//! Minimal JSON parser and emitter (the offline crate set has no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! held as `f64` (sufficient for configs, manifests and result files).
//! The emitter produces deterministic output (object keys keep insertion
//! order) so experiment result files diff cleanly between runs.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys (Vec of pairs keeps emit order
    /// deterministic and preserves the authoring order of config files).
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---- constructors ----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style field insert (replaces an existing key).
    pub fn with(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut pairs) = self {
            let value = value.into();
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = value;
            } else {
                pairs.push((key.to_string(), value));
            }
        } else {
            // staticcheck: allow(R3) -- builder misuse is a programmer bug
            panic!("with() on non-object Json");
        }
        self
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) {
        let v = std::mem::replace(self, Json::Null);
        *self = v.with(key, value);
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Typed field access with a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::json(0, format!("missing field '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::json(0, format!("field '{key}' is not a number")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::json(0, format!("field '{key}' is not a non-negative integer")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::json(0, format!("field '{key}' is not a string")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::json(0, format!("field '{key}' is not an array")))
    }

    /// Sorted-key view for canonical hashing/equality of objects.
    pub fn canonical_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(pairs) => Some(pairs.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }

    // ---- serialization ---------------------------------------------------

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty encoding with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---- parsing -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::json(p.pos, "trailing characters after document"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            // Shortest round-trip float formatting is Rust's default.
            let _ = write!(out, "{x}");
        }
    } else {
        // JSON has no Inf/NaN; encode as null like most tolerant emitters.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect_byte(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::json(self.pos, format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::json(self.pos, format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            None => Err(Error::json(self.pos, "unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(Error::json(self.pos, format!("unexpected byte '{}'", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(Error::json(self.pos, "expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(Error::json(self.pos, "expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::json(self.pos, "unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect_byte(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| {
                                Error::json(self.pos, "invalid unicode escape")
                            })?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(Error::json(self.pos, "bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| Error::json(self.pos, "invalid utf-8"))?;
                    let c = s
                        .chars()
                        .next()
                        .ok_or_else(|| Error::json(self.pos, "unterminated string"))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        self.pos += 1; // past 'u'
        if self.pos + 4 > self.bytes.len() {
            return Err(Error::json(self.pos, "truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| Error::json(self.pos, "bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| Error::json(self.pos, "bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::json(start, "invalid number bytes"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::json(start, format!("invalid number '{s}'")))
    }
}

// ---- From impls for ergonomic construction --------------------------------

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}

impl From<i32> for Json {
    fn from(x: i32) -> Self {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic_document() {
        let doc = Json::obj()
            .with("name", "knl_7210")
            .with("cores", 64usize)
            .with("peak_bw_gbps", 400.0)
            .with("enabled", true)
            .with("tags", vec!["a", "b"])
            .with("nested", Json::obj().with("x", 1.5));
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(doc, back);
        let compact = doc.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), doc);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
        // And re-emits parseably.
        let text = v.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn numbers_parse_in_all_forms() {
        for (s, want) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), want, "{s}");
        }
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::Num(64.0).to_string_compact(), "64");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        match e {
            Error::Json { offset, .. } => assert!(offset > 0),
            other => panic!("wrong error {other}"),
        }
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("[1] trailing").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn typed_accessors() {
        let doc = Json::parse(r#"{"n": 3, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(doc.req_usize("n").unwrap(), 3);
        assert_eq!(doc.req_str("s").unwrap(), "x");
        assert_eq!(doc.req_arr("a").unwrap().len(), 2);
        assert!(doc.req_f64("missing").is_err());
        assert!(doc.req_str("n").is_err());
    }

    #[test]
    fn with_replaces_existing_key() {
        let doc = Json::obj().with("k", 1).with("k", 2);
        assert_eq!(doc.req_f64("k").unwrap(), 2.0);
        if let Json::Obj(pairs) = &doc {
            assert_eq!(pairs.len(), 1);
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::Arr(vec![]).to_string_compact(), "[]");
        assert_eq!(Json::obj().to_string_pretty(), "{}\n");
    }
}
