//! Aggregated sweep results: per-scenario metrics, ranking, rendering.

use super::grid::Scenario;
use crate::shaping::{ShapingAnalysis, ShapingReport};
use crate::util::csv::CsvWriter;
use crate::util::json::Json;
use crate::util::table::Table;
use std::cmp::Ordering;

/// The paper's comparison metrics for one completed scenario, plus the
/// traffic-smoothness (coefficient-of-variation) columns the ranked
/// report sorts and displays.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepMetrics {
    /// throughput(n)/throughput(1) on the same accelerator config.
    pub relative_performance: f64,
    /// 1 − σ_n/σ_1 of the sampled bandwidth series.
    pub std_reduction: f64,
    /// mean_n/mean_1 − 1 of the sampled bandwidth series.
    pub avg_bw_increase: f64,
    /// σ/μ of the shaped bandwidth series — lower is smoother traffic.
    pub smoothness_cov: f64,
    /// σ/μ of the synchronous baseline's series, for reference.
    pub baseline_cov: f64,
    pub bw_mean_gbps: f64,
    pub bw_std_gbps: f64,
    pub makespan_s: f64,
    pub throughput_ips: f64,
}

impl SweepMetrics {
    /// Metrics of a shaped run relative to its baseline.
    pub fn from_report(report: &ShapingReport) -> Self {
        Self {
            relative_performance: report.relative_performance,
            std_reduction: report.std_reduction,
            avg_bw_increase: report.avg_bw_increase,
            smoothness_cov: report.smoothness_cov(),
            baseline_cov: report.baseline.bw.cov(),
            bw_mean_gbps: report.shaped.bw.mean,
            bw_std_gbps: report.shaped.bw.std,
            makespan_s: report.shaped.makespan,
            throughput_ips: report.shaped.throughput,
        }
    }

    /// Metrics of the synchronous baseline itself (the n = 1 grid row).
    pub fn baseline_row(baseline: &ShapingAnalysis) -> Self {
        Self {
            relative_performance: 1.0,
            std_reduction: 0.0,
            avg_bw_increase: 0.0,
            smoothness_cov: baseline.bw.cov(),
            baseline_cov: baseline.bw.cov(),
            bw_mean_gbps: baseline.bw.mean,
            bw_std_gbps: baseline.bw.std,
            makespan_s: baseline.makespan,
            throughput_ips: baseline.throughput,
        }
    }
}

/// What happened to one scenario.
#[derive(Debug, Clone)]
pub enum ScenarioStatus {
    Completed(SweepMetrics),
    /// DRAM-infeasible point (the paper's VGG-16-beyond-8 wall) with the
    /// capacity model's explanation.
    Infeasible(String),
}

/// One scenario plus its result.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub scenario: Scenario,
    pub status: ScenarioStatus,
}

impl ScenarioOutcome {
    pub fn metrics(&self) -> Option<&SweepMetrics> {
        match &self.status {
            ScenarioStatus::Completed(m) => Some(m),
            ScenarioStatus::Infeasible(_) => None,
        }
    }
}

/// The aggregated result of one sweep run. `outcomes` is in scenario-id
/// order regardless of how many worker threads produced it, so renders
/// and CSV exports are byte-identical across thread counts.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub outcomes: Vec<ScenarioOutcome>,
}

impl SweepReport {
    /// Completed outcomes ranked by relative performance (best first,
    /// scenario id as the deterministic tie-breaker), then infeasible
    /// outcomes in id order.
    pub fn ranked(&self) -> Vec<&ScenarioOutcome> {
        let mut out: Vec<&ScenarioOutcome> = self.outcomes.iter().collect();
        out.sort_by(|a, b| match (a.metrics(), b.metrics()) {
            (Some(ma), Some(mb)) => mb
                .relative_performance
                .partial_cmp(&ma.relative_performance)
                .unwrap_or(Ordering::Equal)
                .then(a.scenario.id.cmp(&b.scenario.id)),
            (Some(_), None) => Ordering::Less,
            (None, Some(_)) => Ordering::Greater,
            (None, None) => a.scenario.id.cmp(&b.scenario.id),
        });
        out
    }

    /// The best completed scenario, if any completed at all.
    pub fn best(&self) -> Option<&ScenarioOutcome> {
        self.ranked().into_iter().find(|o| o.metrics().is_some())
    }

    pub fn completed_count(&self) -> usize {
        self.outcomes.iter().filter(|o| o.metrics().is_some()).count()
    }

    pub fn infeasible_count(&self) -> usize {
        self.outcomes.len() - self.completed_count()
    }

    /// Infeasible scenarios with the capacity model's explanation, in
    /// grid order — callers print these as `note:` lines so the DRAM
    /// breakdown (weights/activations/workspace) stays visible.
    pub fn infeasible_reasons(&self) -> Vec<(&Scenario, &str)> {
        self.outcomes
            .iter()
            .filter_map(|o| match &o.status {
                ScenarioStatus::Infeasible(why) => Some((&o.scenario, why.as_str())),
                ScenarioStatus::Completed(_) => None,
            })
            .collect()
    }

    /// Ranked ASCII table (the `sweep` CLI's output).
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "#",
            "model",
            "n",
            "bw",
            "rel perf",
            "σ reduction",
            "avg BW gain",
            "cov",
            "sync cov",
        ])
        .left_first();
        for (rank, o) in self.ranked().iter().enumerate() {
            let s = &o.scenario;
            match o.metrics() {
                Some(m) => t.row(vec![
                    (rank + 1).to_string(),
                    s.model.clone(),
                    s.partitions.to_string(),
                    format!("{:.2}x", s.bandwidth_scale),
                    format!("{:+.1}%", (m.relative_performance - 1.0) * 100.0),
                    format!("{:+.1}%", m.std_reduction * 100.0),
                    format!("{:+.1}%", m.avg_bw_increase * 100.0),
                    format!("{:.3}", m.smoothness_cov),
                    format!("{:.3}", m.baseline_cov),
                ]),
                None => t.row(vec![
                    "-".to_string(),
                    s.model.clone(),
                    s.partitions.to_string(),
                    format!("{:.2}x", s.bandwidth_scale),
                    "DRAM".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ]),
            };
        }
        t.title("scenario sweep — ranked by relative performance vs synchronous baseline")
            .render()
    }

    /// Full per-scenario export in grid (id) order.
    pub fn to_csv(&self) -> CsvWriter {
        let mut w = CsvWriter::new(vec![
            "id",
            "model",
            "partitions",
            "bandwidth_scale",
            "steady_batches",
            "status",
            "relative_performance",
            "std_reduction",
            "avg_bw_increase",
            "smoothness_cov",
            "baseline_cov",
            "bw_mean_gbps",
            "bw_std_gbps",
            "makespan_s",
            "throughput_ips",
            "reason",
        ]);
        let f = crate::util::csv::format_float;
        for o in &self.outcomes {
            let s = &o.scenario;
            let head = vec![
                s.id.to_string(),
                s.model.clone(),
                s.partitions.to_string(),
                f(s.bandwidth_scale),
                s.steady_batches.to_string(),
            ];
            let tail = match &o.status {
                ScenarioStatus::Completed(m) => vec![
                    "ok".to_string(),
                    f(m.relative_performance),
                    f(m.std_reduction),
                    f(m.avg_bw_increase),
                    f(m.smoothness_cov),
                    f(m.baseline_cov),
                    f(m.bw_mean_gbps),
                    f(m.bw_std_gbps),
                    f(m.makespan_s),
                    f(m.throughput_ips),
                    String::new(),
                ],
                ScenarioStatus::Infeasible(why) => {
                    let mut v = vec!["dram_infeasible".to_string()];
                    v.extend((0..9).map(|_| String::new()));
                    v.push(why.clone());
                    v
                }
            };
            w.row(head.into_iter().chain(tail).collect());
        }
        w
    }

    /// Summary for result files: counts plus the best point per model.
    pub fn summary_json(&self) -> Json {
        let mut j = Json::obj()
            .with("scenarios", self.outcomes.len())
            .with("completed", self.completed_count())
            .with("dram_infeasible", self.infeasible_count());
        if let Some(best) = self.best() {
            j.set(
                "best",
                Json::obj()
                    .with("label", best.scenario.label())
                    .with(
                        "relative_performance",
                        best.metrics().map(|m| m.relative_performance).unwrap_or(0.0),
                    ),
            );
        }
        for o in self.ranked() {
            if let Some(m) = o.metrics() {
                let key = format!("best_gain_{}", o.scenario.model);
                if j.get(&key).is_none() {
                    j.set(&key, m.relative_performance);
                }
            }
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(rel: f64) -> SweepMetrics {
        SweepMetrics {
            relative_performance: rel,
            std_reduction: 0.1,
            avg_bw_increase: 0.05,
            smoothness_cov: 0.2,
            baseline_cov: 0.5,
            bw_mean_gbps: 200.0,
            bw_std_gbps: 40.0,
            makespan_s: 1.0,
            throughput_ips: 64.0,
        }
    }

    fn outcome(id: usize, rel: Option<f64>) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario: Scenario {
                id,
                model: "resnet50".into(),
                partitions: 2,
                bandwidth_scale: 1.0,
                steady_batches: 4,
            },
            status: match rel {
                Some(r) => ScenarioStatus::Completed(metrics(r)),
                None => ScenarioStatus::Infeasible("over capacity".into()),
            },
        }
    }

    #[test]
    fn ranking_sorts_best_first_and_infeasible_last() {
        let r = SweepReport {
            outcomes: vec![
                outcome(0, Some(1.02)),
                outcome(1, None),
                outcome(2, Some(1.10)),
                outcome(3, Some(1.10)),
            ],
        };
        let ranked = r.ranked();
        assert_eq!(ranked[0].scenario.id, 2, "highest gain first, id breaks the tie");
        assert_eq!(ranked[1].scenario.id, 3);
        assert_eq!(ranked[2].scenario.id, 0);
        assert_eq!(ranked[3].scenario.id, 1, "infeasible sinks to the bottom");
        assert_eq!(r.best().unwrap().scenario.id, 2);
        assert_eq!(r.completed_count(), 3);
        assert_eq!(r.infeasible_count(), 1);
    }

    #[test]
    fn render_and_csv_cover_all_rows() {
        let r = SweepReport { outcomes: vec![outcome(0, Some(1.05)), outcome(1, None)] };
        let text = r.render();
        assert!(text.contains("ranked by relative performance"));
        assert!(text.contains("+5.0%"));
        assert!(text.contains("DRAM"));
        let csv = r.to_csv().to_string();
        assert_eq!(csv.lines().count(), 3); // header + 2 rows
        assert!(csv.contains("dram_infeasible"));
        let j = r.summary_json();
        assert_eq!(j.req_usize("scenarios").unwrap(), 2);
        assert!(j.req_f64("best_gain_resnet50").unwrap() > 1.0);
    }
}
