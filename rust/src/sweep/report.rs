//! Aggregated sweep results: per-scenario metrics, ranking, rendering.

use super::grid::Scenario;
use super::replicate::{MetricCi, ReplicatedMetrics};
use crate::serve::ServeOutcome;
use crate::shaping::{ShapingAnalysis, ShapingReport};
use crate::util::csv::CsvWriter;
use crate::util::stats::Confidence;
use crate::util::json::Json;
use crate::util::table::Table;
use std::cmp::Ordering;

/// The paper's comparison metrics for one completed scenario, plus the
/// traffic-smoothness (coefficient-of-variation) columns and — for
/// serving scenarios — the request-latency percentiles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepMetrics {
    /// throughput(n)/throughput(1) on the same accelerator config (and,
    /// for serve rows, the same arrival stream).
    pub relative_performance: f64,
    /// 1 − σ_n/σ_1 of the sampled bandwidth series.
    pub std_reduction: f64,
    /// mean_n/mean_1 − 1 of the sampled bandwidth series.
    pub avg_bw_increase: f64,
    /// σ/μ of the shaped bandwidth series — lower is smoother traffic.
    pub smoothness_cov: f64,
    /// σ/μ of the synchronous baseline's series, for reference.
    pub baseline_cov: f64,
    pub bw_mean_gbps: f64,
    pub bw_std_gbps: f64,
    pub makespan_s: f64,
    pub throughput_ips: f64,
    /// Latency percentiles — `Some` only for serving scenarios.
    pub p50_ms: Option<f64>,
    pub p95_ms: Option<f64>,
    pub p99_ms: Option<f64>,
    /// Overload accounting — `Some` only for serving scenarios.
    pub drop_rate: Option<f64>,
    pub goodput_ips: Option<f64>,
    /// Mean ± 95 % CI over the replications of the six serve headline
    /// metrics — `Some` only on serve rows of a `--replications N > 1`
    /// sweep. The point-estimate columns above stay replication 0.
    pub replicated: Option<ReplicatedMetrics>,
    /// Mean ± 95 % CI of the relative-performance column across
    /// replications (each replication compared to the same-seed
    /// baseline). Ranking uses this mean when present.
    pub relative_performance_ci: Option<MetricCi>,
}

impl SweepMetrics {
    /// Metrics of a shaped offline run relative to its baseline.
    pub fn from_report(report: &ShapingReport) -> Self {
        Self {
            relative_performance: report.relative_performance,
            std_reduction: report.std_reduction,
            avg_bw_increase: report.avg_bw_increase,
            smoothness_cov: report.smoothness_cov(),
            baseline_cov: report.baseline.bw.cov(),
            bw_mean_gbps: report.shaped.bw.mean,
            bw_std_gbps: report.shaped.bw.std,
            makespan_s: report.shaped.makespan,
            throughput_ips: report.shaped.throughput,
            p50_ms: None,
            p95_ms: None,
            p99_ms: None,
            drop_rate: None,
            goodput_ips: None,
            replicated: None,
            relative_performance_ci: None,
        }
    }

    /// Metrics of the synchronous offline baseline itself (n = 1).
    pub fn baseline_row(baseline: &ShapingAnalysis) -> Self {
        Self {
            relative_performance: 1.0,
            std_reduction: 0.0,
            avg_bw_increase: 0.0,
            smoothness_cov: baseline.bw.cov(),
            baseline_cov: baseline.bw.cov(),
            bw_mean_gbps: baseline.bw.mean,
            bw_std_gbps: baseline.bw.std,
            makespan_s: baseline.makespan,
            throughput_ips: baseline.throughput,
            p50_ms: None,
            p95_ms: None,
            p99_ms: None,
            drop_rate: None,
            goodput_ips: None,
            replicated: None,
            relative_performance_ci: None,
        }
    }

    /// Metrics of a serving run relative to its 1-partition serve
    /// baseline at the same arrival stream.
    pub fn from_serve(out: &ServeOutcome, base: &ServeOutcome) -> Self {
        Self {
            relative_performance: if base.throughput_ips > 0.0 {
                out.throughput_ips / base.throughput_ips
            } else {
                0.0
            },
            std_reduction: if base.bw.std > 0.0 { 1.0 - out.bw.std / base.bw.std } else { 0.0 },
            avg_bw_increase: if base.bw.mean > 0.0 {
                out.bw.mean / base.bw.mean - 1.0
            } else {
                0.0
            },
            smoothness_cov: out.bw.cov(),
            baseline_cov: base.bw.cov(),
            bw_mean_gbps: out.bw.mean,
            bw_std_gbps: out.bw.std,
            makespan_s: out.makespan_s,
            throughput_ips: out.throughput_ips,
            p50_ms: Some(out.latency.p50_ms),
            p95_ms: Some(out.latency.p95_ms),
            p99_ms: Some(out.latency.p99_ms),
            drop_rate: Some(out.drop_rate),
            goodput_ips: Some(out.goodput_ips),
            replicated: None,
            relative_performance_ci: None,
        }
    }

    /// Metrics of the 1-partition serve baseline itself.
    pub fn serve_baseline_row(base: &ServeOutcome) -> Self {
        Self {
            relative_performance: 1.0,
            std_reduction: 0.0,
            avg_bw_increase: 0.0,
            ..Self::from_serve(base, base)
        }
    }

    /// The value ranking sorts on: the *lower* edge of the replication
    /// confidence interval when CI statistics ran (a scenario must beat
    /// another across its whole interval to outrank it), the single-run
    /// point estimate otherwise. At `--replications 1` the interval
    /// half-width is 0, so this equals the mean and ranks are
    /// byte-identical to the classic single-run path.
    pub fn rank_value(&self) -> f64 {
        self.relative_performance_ci.map_or(self.relative_performance, |c| c.lower_bound())
    }

    /// First tie-breaker under [`Self::rank_value`]: the point estimate
    /// (replication mean when CI statistics ran), so equal lower bounds
    /// order by the better central tendency before falling back to id.
    pub fn rank_mean(&self) -> f64 {
        self.relative_performance_ci.map_or(self.relative_performance, |c| c.mean)
    }

    /// Attach replication statistics folded from the per-replication
    /// metrics rows (replication-index order; `self` is replication 0's
    /// row, which keeps the headline point-estimate columns).
    pub(crate) fn fold_replications(
        &mut self,
        reps: &[SweepMetrics],
        confidence: Confidence,
    ) {
        let rows: Vec<[f64; 6]> = reps
            .iter()
            .map(|m| {
                [
                    m.p50_ms.unwrap_or(0.0),
                    m.p95_ms.unwrap_or(0.0),
                    m.p99_ms.unwrap_or(0.0),
                    m.throughput_ips,
                    m.goodput_ips.unwrap_or(0.0),
                    m.drop_rate.unwrap_or(0.0),
                ]
            })
            .collect();
        self.replicated = Some(ReplicatedMetrics::from_rows_at(&rows, confidence));
        let rels: Vec<f64> = reps.iter().map(|m| m.relative_performance).collect();
        self.relative_performance_ci = Some(MetricCi::of_at(&rels, confidence));
    }
}

/// What happened to one scenario.
#[derive(Debug, Clone)]
pub enum ScenarioStatus {
    Completed(SweepMetrics),
    /// DRAM-infeasible point (the paper's VGG-16-beyond-8 wall) with the
    /// capacity model's explanation.
    Infeasible(String),
}

/// One scenario plus its result.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub scenario: Scenario,
    pub status: ScenarioStatus,
}

impl ScenarioOutcome {
    pub fn metrics(&self) -> Option<&SweepMetrics> {
        match &self.status {
            ScenarioStatus::Completed(m) => Some(m),
            ScenarioStatus::Infeasible(_) => None,
        }
    }
}

/// The aggregated result of one sweep run. `outcomes` is in scenario-id
/// order regardless of how many worker threads produced it, so renders
/// and CSV exports are byte-identical across thread counts.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub outcomes: Vec<ScenarioOutcome>,
}

impl SweepReport {
    /// Completed outcomes ranked by relative performance (the lower
    /// 95 % confidence bound when replication statistics ran; best
    /// first, with the replication mean and then the scenario id as
    /// deterministic tie-breakers), then infeasible outcomes in id
    /// order.
    pub fn ranked(&self) -> Vec<&ScenarioOutcome> {
        let mut out: Vec<&ScenarioOutcome> = self.outcomes.iter().collect();
        out.sort_by(|a, b| match (a.metrics(), b.metrics()) {
            (Some(ma), Some(mb)) => mb
                .rank_value()
                .partial_cmp(&ma.rank_value())
                .unwrap_or(Ordering::Equal)
                .then(
                    mb.rank_mean()
                        .partial_cmp(&ma.rank_mean())
                        .unwrap_or(Ordering::Equal),
                )
                .then(a.scenario.id.cmp(&b.scenario.id)),
            (Some(_), None) => Ordering::Less,
            (None, Some(_)) => Ordering::Greater,
            (None, None) => a.scenario.id.cmp(&b.scenario.id),
        });
        out
    }

    /// The best completed scenario, if any completed at all.
    pub fn best(&self) -> Option<&ScenarioOutcome> {
        self.ranked().into_iter().find(|o| o.metrics().is_some())
    }

    pub fn completed_count(&self) -> usize {
        self.outcomes.iter().filter(|o| o.metrics().is_some()).count()
    }

    pub fn infeasible_count(&self) -> usize {
        self.outcomes.len() - self.completed_count()
    }

    /// Serving scenarios in the report (rows with latency percentiles).
    pub fn serve_count(&self) -> usize {
        self.outcomes.iter().filter(|o| o.scenario.is_serve()).count()
    }

    /// Whether any row carries replication statistics (a
    /// `--replications N > 1` sweep), i.e. whether CI columns appear.
    pub fn is_replicated(&self) -> bool {
        self.outcomes.iter().any(|o| o.metrics().is_some_and(|m| m.replicated.is_some()))
    }

    /// The replication count of the sweep (`None` for single-run
    /// sweeps).
    pub fn replications(&self) -> Option<usize> {
        self.outcomes
            .iter()
            .filter_map(|o| o.metrics().and_then(|m| m.replicated.map(|r| r.replications())))
            .max()
    }

    /// Infeasible scenarios with the capacity model's explanation, in
    /// grid order — callers print these as `note:` lines so the DRAM
    /// breakdown (weights/activations/workspace) stays visible.
    pub fn infeasible_reasons(&self) -> Vec<(&Scenario, &str)> {
        self.outcomes
            .iter()
            .filter_map(|o| match &o.status {
                ScenarioStatus::Infeasible(why) => Some((&o.scenario, why.as_str())),
                ScenarioStatus::Completed(_) => None,
            })
            .collect()
    }

    /// Ranked ASCII table (the `sweep` CLI's output). Replicated sweeps
    /// append a `rel ±ci` column: the relative-performance gain as mean
    /// ± 95 % CI over the replications, in percent.
    pub fn render(&self) -> String {
        let replicated = self.is_replicated();
        let mut cols = vec![
            "#",
            "model",
            "n",
            "bw",
            "stagger",
            "λ img/s",
            "cap/slo",
            "rel perf",
            "σ reduction",
            "avg BW gain",
            "cov",
            "sync cov",
            "p99 ms",
            "drop %",
        ];
        if replicated {
            cols.push("rel ±ci");
        }
        let mut t = Table::new(cols).left_first();
        for (rank, o) in self.ranked().iter().enumerate() {
            let s = &o.scenario;
            let rate = if s.is_serve() { format!("{:.0}", s.arrival_rate) } else { "-".into() };
            let cap_slo = if s.is_serve() && (s.queue_cap > 0 || s.slo_ms > 0.0) {
                format!("{}/{:.0}", s.queue_cap, s.slo_ms)
            } else {
                "-".to_string()
            };
            let opt = |v: Option<String>| v.unwrap_or_else(|| "-".to_string());
            match o.metrics() {
                Some(m) => {
                    let mut row = vec![
                        (rank + 1).to_string(),
                        s.model.clone(),
                        s.partitions.to_string(),
                        format!("{:.2}x", s.bandwidth_scale),
                        s.stagger.name().to_string(),
                        rate,
                        cap_slo,
                        format!("{:+.1}%", (m.relative_performance - 1.0) * 100.0),
                        format!("{:+.1}%", m.std_reduction * 100.0),
                        format!("{:+.1}%", m.avg_bw_increase * 100.0),
                        format!("{:.3}", m.smoothness_cov),
                        format!("{:.3}", m.baseline_cov),
                        opt(m.p99_ms.map(|p| format!("{p:.1}"))),
                        opt(m.drop_rate.map(|d| format!("{:.1}", d * 100.0))),
                    ];
                    if replicated {
                        row.push(opt(m.relative_performance_ci.map(|c| {
                            format!("{:+.1}±{:.1}%", (c.mean - 1.0) * 100.0, c.ci * 100.0)
                        })));
                    }
                    t.row(row)
                }
                None => {
                    let mut row = vec![
                        "-".to_string(),
                        s.model.clone(),
                        s.partitions.to_string(),
                        format!("{:.2}x", s.bandwidth_scale),
                        s.stagger.name().to_string(),
                        rate,
                        cap_slo,
                        "DRAM".to_string(),
                    ];
                    row.extend((0..6).map(|_| "-".to_string()));
                    if replicated {
                        row.push("-".to_string());
                    }
                    t.row(row)
                }
            };
        }
        t.title("scenario sweep — ranked by relative performance vs synchronous baseline")
            .render()
    }

    /// The CSV header of [`Self::to_csv`]. The single-run header is a
    /// strict prefix of the replicated one: `--replications N > 1`
    /// appends the relative-performance mean/CI pair followed by the
    /// [`ReplicatedMetrics::CSV_COLUMNS`] pairs.
    pub fn csv_columns(replicated: bool) -> Vec<&'static str> {
        let mut cols = vec![
            "id",
            "model",
            "partitions",
            "bandwidth_scale",
            "stagger",
            "arrival_rate",
            "queue_cap",
            "slo_ms",
            "steady_batches",
            "tenants",
            "status",
            "relative_performance",
            "std_reduction",
            "avg_bw_increase",
            "smoothness_cov",
            "baseline_cov",
            "bw_mean_gbps",
            "bw_std_gbps",
            "makespan_s",
            "throughput_ips",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "drop_rate",
            "goodput_ips",
            "reason",
        ];
        if replicated {
            cols.push("relative_performance_mean");
            cols.push("relative_performance_ci95");
            cols.extend(ReplicatedMetrics::CSV_COLUMNS);
        }
        cols
    }

    /// [`Self::csv_columns`] at an explicit coverage level: identical
    /// at the default 95 %, interval suffixes renamed otherwise.
    pub fn csv_columns_at(replicated: bool, confidence: Confidence) -> Vec<String> {
        let mut cols: Vec<String> =
            Self::csv_columns(false).into_iter().map(str::to_string).collect();
        if replicated {
            cols.push("relative_performance_mean".to_string());
            cols.push(format!("relative_performance_{}", confidence.suffix()));
            cols.extend(ReplicatedMetrics::csv_columns_at(confidence));
        }
        cols
    }

    /// The interval coverage of the replication folds (the default when
    /// nothing replicated).
    pub fn confidence(&self) -> Confidence {
        self.outcomes
            .iter()
            .filter_map(|o| o.metrics().and_then(|m| m.replicated))
            .map(|r| r.confidence())
            .next()
            .unwrap_or_default()
    }

    /// Full per-scenario export in grid (id) order. Replicated sweeps
    /// append the mean/CI column pairs (empty on offline and infeasible
    /// rows — only serve rows replicate).
    pub fn to_csv(&self) -> CsvWriter {
        let replicated = self.is_replicated();
        let mut w = CsvWriter::new(Self::csv_columns_at(replicated, self.confidence()));
        let f = crate::util::csv::format_float;
        let opt = |v: Option<f64>| v.map(f).unwrap_or_default();
        for o in &self.outcomes {
            let s = &o.scenario;
            let head = vec![
                s.id.to_string(),
                s.model.clone(),
                s.partitions.to_string(),
                f(s.bandwidth_scale),
                s.stagger.name().to_string(),
                f(s.arrival_rate),
                s.queue_cap.to_string(),
                f(s.slo_ms),
                s.steady_batches.to_string(),
                // Tenant specs are comma-separated; the CSV cell swaps in
                // ';' so the row stays machine-parseable without quoting.
                s.tenants.clone().unwrap_or_default().replace(',', ";"),
            ];
            let tail = match &o.status {
                ScenarioStatus::Completed(m) => vec![
                    "ok".to_string(),
                    f(m.relative_performance),
                    f(m.std_reduction),
                    f(m.avg_bw_increase),
                    f(m.smoothness_cov),
                    f(m.baseline_cov),
                    f(m.bw_mean_gbps),
                    f(m.bw_std_gbps),
                    f(m.makespan_s),
                    f(m.throughput_ips),
                    opt(m.p50_ms),
                    opt(m.p95_ms),
                    opt(m.p99_ms),
                    opt(m.drop_rate),
                    opt(m.goodput_ips),
                    String::new(),
                ],
                ScenarioStatus::Infeasible(why) => {
                    let mut v = vec!["dram_infeasible".to_string()];
                    v.extend((0..14).map(|_| String::new()));
                    v.push(why.clone());
                    v
                }
            };
            let mut cells: Vec<String> = head.into_iter().chain(tail).collect();
            if replicated {
                match o.metrics().and_then(|m| m.replicated.map(|r| (m, r))) {
                    Some((m, r)) => {
                        let ci = m.relative_performance_ci.unwrap_or(MetricCi {
                            n: 0,
                            mean: m.relative_performance,
                            std: 0.0,
                            ci: 0.0,
                            confidence: r.confidence(),
                        });
                        cells.push(f(ci.mean));
                        cells.push(f(ci.ci));
                        cells.extend(r.csv_cells());
                    }
                    None => {
                        let extra = 2 + ReplicatedMetrics::CSV_COLUMNS.len();
                        cells.extend((0..extra).map(|_| String::new()));
                    }
                }
            }
            w.row(cells);
        }
        w
    }

    /// Summary for result files: counts plus the best point per model.
    pub fn summary_json(&self) -> Json {
        let mut j = Json::obj()
            .with("scenarios", self.outcomes.len())
            .with("completed", self.completed_count())
            .with("dram_infeasible", self.infeasible_count())
            .with("serve_scenarios", self.serve_count());
        // Replication keys appear only on replicated sweeps, keeping the
        // --replications 1 summary byte-identical to the classic one.
        if let Some(r) = self.replications() {
            j.set("replications", r);
        }
        if let Some(best) = self.best() {
            let mut b = Json::obj().with("label", best.scenario.label()).with(
                "relative_performance",
                best.metrics().map(|m| m.relative_performance).unwrap_or(0.0),
            );
            if let Some(ci) = best.metrics().and_then(|m| m.relative_performance_ci) {
                b = b
                    .with("relative_performance_mean", ci.mean)
                    .with(&format!("relative_performance_{}", ci.confidence.suffix()), ci.ci);
            }
            j.set("best", b);
        }
        for o in self.ranked() {
            if let Some(m) = o.metrics() {
                let key = format!("best_gain_{}", o.scenario.model);
                if j.get(&key).is_none() {
                    j.set(&key, m.relative_performance);
                }
            }
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shaping::StaggerPolicy;

    fn metrics(rel: f64) -> SweepMetrics {
        SweepMetrics {
            relative_performance: rel,
            std_reduction: 0.1,
            avg_bw_increase: 0.05,
            smoothness_cov: 0.2,
            baseline_cov: 0.5,
            bw_mean_gbps: 200.0,
            bw_std_gbps: 40.0,
            makespan_s: 1.0,
            throughput_ips: 64.0,
            p50_ms: None,
            p95_ms: None,
            p99_ms: None,
            drop_rate: None,
            goodput_ips: None,
            replicated: None,
            relative_performance_ci: None,
        }
    }

    fn outcome(id: usize, rel: Option<f64>) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario: Scenario {
                id,
                model: "resnet50".into(),
                partitions: 2,
                bandwidth_scale: 1.0,
                stagger: StaggerPolicy::UniformPhase,
                arrival_rate: 0.0,
                queue_cap: 0,
                slo_ms: 0.0,
                steady_batches: 4,
                tenants: None,
            },
            status: match rel {
                Some(r) => ScenarioStatus::Completed(metrics(r)),
                None => ScenarioStatus::Infeasible("over capacity".into()),
            },
        }
    }

    fn serve_outcome(id: usize, p99: f64) -> ScenarioOutcome {
        let mut o = outcome(id, Some(1.04));
        o.scenario.arrival_rate = 500.0;
        if let ScenarioStatus::Completed(m) = &mut o.status {
            m.p50_ms = Some(p99 / 4.0);
            m.p95_ms = Some(p99 / 2.0);
            m.p99_ms = Some(p99);
            m.drop_rate = Some(0.25);
            m.goodput_ips = Some(48.0);
        }
        o
    }

    #[test]
    fn ranking_sorts_best_first_and_infeasible_last() {
        let r = SweepReport {
            outcomes: vec![
                outcome(0, Some(1.02)),
                outcome(1, None),
                outcome(2, Some(1.10)),
                outcome(3, Some(1.10)),
            ],
        };
        let ranked = r.ranked();
        assert_eq!(ranked[0].scenario.id, 2, "highest gain first, id breaks the tie");
        assert_eq!(ranked[1].scenario.id, 3);
        assert_eq!(ranked[2].scenario.id, 0);
        assert_eq!(ranked[3].scenario.id, 1, "infeasible sinks to the bottom");
        assert_eq!(r.best().unwrap().scenario.id, 2);
        assert_eq!(r.completed_count(), 3);
        assert_eq!(r.infeasible_count(), 1);
    }

    #[test]
    fn render_and_csv_cover_all_rows() {
        let r = SweepReport { outcomes: vec![outcome(0, Some(1.05)), outcome(1, None)] };
        let text = r.render();
        assert!(text.contains("ranked by relative performance"));
        assert!(text.contains("+5.0%"));
        assert!(text.contains("DRAM"));
        assert!(text.contains("p99 ms"));
        let csv = r.to_csv().to_string();
        assert_eq!(csv.lines().count(), 3); // header + 2 rows
        assert!(csv.contains("dram_infeasible"));
        assert!(csv.contains(",stagger,arrival_rate,"));
        let j = r.summary_json();
        assert_eq!(j.req_usize("scenarios").unwrap(), 2);
        assert_eq!(j.req_usize("serve_scenarios").unwrap(), 0);
        assert!(j.req_f64("best_gain_resnet50").unwrap() > 1.0);
    }

    #[test]
    fn serve_rows_carry_latency_columns() {
        let r = SweepReport { outcomes: vec![serve_outcome(0, 80.0), outcome(1, Some(1.02))] };
        assert_eq!(r.serve_count(), 1);
        let text = r.render();
        assert!(text.contains("80.0"));
        // The grid axes show up as columns: stagger name + arrival rate.
        assert!(text.contains("uniform_phase"));
        assert!(text.contains("500"));
        let csv = r.to_csv().to_string();
        // The serve row exports percentiles; the offline row leaves the
        // latency cells empty.
        assert!(csv.contains(",20,40,80,"));
        assert!(csv.contains(",uniform_phase,500,"));
        let j = r.summary_json();
        assert_eq!(j.req_usize("serve_scenarios").unwrap(), 1);
    }

    #[test]
    fn replicated_rows_fold_ci_and_drive_ranking() {
        // Two serve rows: row 0 has the better single-seed (rep 0)
        // estimate, row 1 the better replication mean AND the tighter
        // interval — its lower confidence bound wins the ranking.
        let mut a = serve_outcome(0, 80.0);
        let mut b = serve_outcome(1, 60.0);
        let per_rep = |rels: &[f64]| {
            rels.iter()
                .map(|&r| {
                    let mut m = metrics(r);
                    m.p99_ms = Some(50.0 + r);
                    m.throughput_ips = 64.0 * r;
                    m
                })
                .collect::<Vec<_>>()
        };
        if let ScenarioStatus::Completed(m) = &mut a.status {
            m.relative_performance = 1.10;
            m.fold_replications(&per_rep(&[1.10, 1.00, 0.99]), Confidence::default());
        }
        if let ScenarioStatus::Completed(m) = &mut b.status {
            m.relative_performance = 1.04;
            m.fold_replications(&per_rep(&[1.04, 1.08, 1.09]), Confidence::default());
        }
        let r = SweepReport { outcomes: vec![a, b, outcome(2, None)] };
        assert!(r.is_replicated());
        assert_eq!(r.replications(), Some(3));
        assert_eq!(r.ranked()[0].scenario.id, 1, "CI lower bound outranks the rep-0 estimate");
        let m = r.outcomes[0].metrics().unwrap();
        let ci = m.relative_performance_ci.unwrap();
        assert!((ci.mean - (1.10 + 1.00 + 0.99) / 3.0).abs() < 1e-12);
        assert!(ci.ci > 0.0);
        assert_eq!(m.replicated.unwrap().replications(), 3);
        let csv = r.to_csv().to_string();
        let header = csv.lines().next().unwrap();
        assert!(header.contains(",reason,relative_performance_mean,relative_performance_ci95,"));
        assert!(header.ends_with(",drop_rate_mean,drop_rate_ci95"));
        // The infeasible row pads the CI cells out empty.
        let infeasible_line = csv.lines().last().unwrap();
        assert!(infeasible_line.ends_with(",,,,,,,,,,,,,"));
        assert!(r.render().contains("rel ±ci"));
        assert!(r.render().contains('±'));
        assert_eq!(r.summary_json().req_usize("replications").unwrap(), 3);
        // A single-run report keeps the classic header and no CI column.
        let plain = SweepReport { outcomes: vec![outcome(0, Some(1.02))] };
        assert!(!plain.is_replicated());
        assert!(plain.to_csv().to_string().lines().next().unwrap().ends_with(",reason"));
        assert!(!plain.render().contains("rel ±ci"));
    }

    #[test]
    fn ranking_prefers_tight_intervals_over_wide_means() {
        // Row 0: higher mean but a wide interval (noisy seeds). Row 1:
        // lower mean, tight interval. The conservative lower bound
        // ranks the defensible row first.
        let mut a = outcome(0, Some(1.20));
        let mut b = outcome(1, Some(1.06));
        if let ScenarioStatus::Completed(m) = &mut a.status {
            m.relative_performance_ci =
                Some(MetricCi::of_at(&[1.40, 1.20, 1.00], Confidence::default()));
        }
        if let ScenarioStatus::Completed(m) = &mut b.status {
            m.relative_performance_ci =
                Some(MetricCi::of_at(&[1.07, 1.06, 1.05], Confidence::default()));
        }
        let ma = a.metrics().unwrap().rank_value();
        let mb = b.metrics().unwrap().rank_value();
        assert!(mb > ma, "tight interval ({mb:.3}) must outrank wide one ({ma:.3})");
        let r = SweepReport { outcomes: vec![a, b] };
        assert_eq!(r.ranked()[0].scenario.id, 1);
        assert_eq!(r.best().unwrap().scenario.id, 1);
    }

    #[test]
    fn ranking_breaks_lower_bound_ties_by_mean_then_id() {
        // Hand-built intervals with identical lower bounds: 1.10−0.10
        // and 1.05−0.05 both bound at 1.00; the higher mean wins.
        let ci = |mean: f64, half: f64| MetricCi {
            n: 3,
            mean,
            std: 0.0,
            ci: half,
            confidence: Confidence::default(),
        };
        let mut a = outcome(0, Some(1.05));
        let mut b = outcome(1, Some(1.10));
        if let ScenarioStatus::Completed(m) = &mut a.status {
            m.relative_performance_ci = Some(ci(1.05, 0.05));
        }
        if let ScenarioStatus::Completed(m) = &mut b.status {
            m.relative_performance_ci = Some(ci(1.10, 0.10));
        }
        let r = SweepReport { outcomes: vec![a, b] };
        assert_eq!(r.ranked()[0].scenario.id, 1, "equal bounds: mean breaks the tie");
        // Fully identical intervals fall back to scenario id.
        let mut c = outcome(5, Some(1.05));
        let mut d = outcome(4, Some(1.05));
        for o in [&mut c, &mut d] {
            if let ScenarioStatus::Completed(m) = &mut o.status {
                m.relative_performance_ci = Some(ci(1.05, 0.05));
            }
        }
        let r = SweepReport { outcomes: vec![c, d] };
        assert_eq!(r.ranked()[0].scenario.id, 4, "identical stats: id orders");
    }

    #[test]
    fn serve_metrics_compare_against_baseline() {
        use crate::serve::{LatencyStats, ServeOutcome};
        use crate::sim::BandwidthTrace;
        use crate::util::stats::Summary;
        let mk = |thr: f64, std: f64, p99: f64| ServeOutcome {
            partitions: 1,
            arrival_rate: 100.0,
            requests: 10,
            served: 9,
            dropped: 1,
            drop_rate: 0.1,
            batches: 9,
            mean_batch: 1.0,
            queue_peak: 3,
            makespan_s: 1.0,
            throughput_ips: thr,
            goodput_ips: thr * 0.9,
            latency: LatencyStats {
                count: 9,
                dropped: 1,
                slo_hits: 8,
                mean_ms: p99 / 2.0,
                p50_ms: p99 / 4.0,
                p95_ms: p99 / 2.0,
                p99_ms: p99,
                max_ms: p99,
            },
            bw: Summary { count: 8, mean: 100.0, std, min: 0.0, max: 200.0 },
            total_bytes: 1e9,
            trace: BandwidthTrace::total_only(),
            epochs: Vec::new(),
            reconfigs: Vec::new(),
            arrival_times_s: Vec::new(),
            finish_times_s: Vec::new(),
        };
        let base = mk(100.0, 50.0, 80.0);
        let shaped = mk(108.0, 40.0, 50.0);
        let m = SweepMetrics::from_serve(&shaped, &base);
        assert!((m.relative_performance - 1.08).abs() < 1e-12);
        assert!((m.std_reduction - 0.2).abs() < 1e-12);
        assert_eq!(m.p99_ms, Some(50.0));
        assert_eq!(m.drop_rate, Some(0.1));
        assert_eq!(m.goodput_ips, Some(108.0 * 0.9));
        let b = SweepMetrics::serve_baseline_row(&base);
        assert_eq!(b.relative_performance, 1.0);
        assert_eq!(b.p99_ms, Some(80.0));
        assert_eq!(b.drop_rate, Some(0.1));
    }
}
