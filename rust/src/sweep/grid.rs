//! Scenario grids: the cartesian product of models × partition counts ×
//! bandwidth configurations a sweep explores.

use crate::config::AcceleratorConfig;
use crate::error::{Error, Result};
use crate::util::units::BytesPerS;

/// The model zoo a default sweep covers (the paper's three evaluation
/// networks plus AlexNet and the e2e TinyCNN).
pub const DEFAULT_SWEEP_MODELS: [&str; 5] = ["vgg16", "googlenet", "resnet50", "alexnet", "tiny"];

/// One point of the sweep grid. `id` is the point's index in the grid's
/// enumeration order and the key that makes parallel execution
/// order-independent: results are always reported in `id` order, no
/// matter which worker thread computed them.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub id: usize,
    pub model: String,
    pub partitions: usize,
    /// Multiplier on the accelerator's sustained memory bandwidth —
    /// sweeping it explores how the shaping win moves with the
    /// compute/bandwidth balance (cf. the unlimited-BW ablation).
    pub bandwidth_scale: f64,
    pub steady_batches: usize,
}

impl Scenario {
    /// Human-readable tag used in reports and logs.
    pub fn label(&self) -> String {
        format!("{}@{}p/bw{:.2}x", self.model, self.partitions, self.bandwidth_scale)
    }

    /// The accelerator this scenario runs on: `base` with the bandwidth
    /// knob scaled.
    pub fn accel(&self, base: &AcceleratorConfig) -> AcceleratorConfig {
        let mut a = base.clone();
        a.mem_bw = BytesPerS(base.mem_bw.0 * self.bandwidth_scale);
        a
    }
}

/// Builder for a sweep grid. `scenarios()` enumerates the cartesian
/// product model-major, then bandwidth scale, then partition count — the
/// order every report uses.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub accel: AcceleratorConfig,
    pub models: Vec<String>,
    pub partitions: Vec<usize>,
    pub bandwidth_scales: Vec<f64>,
    pub steady_batches: usize,
    pub trace_samples: usize,
}

impl SweepGrid {
    pub fn new(accel: &AcceleratorConfig) -> Self {
        Self {
            accel: accel.clone(),
            models: DEFAULT_SWEEP_MODELS.iter().map(|s| s.to_string()).collect(),
            partitions: vec![1, 2, 4, 8, 16],
            bandwidth_scales: vec![1.0],
            steady_batches: 6,
            trace_samples: 400,
        }
    }

    pub fn models<S: Into<String>>(mut self, models: Vec<S>) -> Self {
        self.models = models.into_iter().map(Into::into).collect();
        self
    }

    pub fn partitions(mut self, partitions: Vec<usize>) -> Self {
        self.partitions = partitions;
        self
    }

    pub fn bandwidth_scales(mut self, scales: Vec<f64>) -> Self {
        self.bandwidth_scales = scales;
        self
    }

    pub fn steady_batches(mut self, batches: usize) -> Self {
        self.steady_batches = batches;
        self
    }

    pub fn trace_samples(mut self, samples: usize) -> Self {
        self.trace_samples = samples;
        self
    }

    /// Number of scenarios the grid enumerates.
    pub fn len(&self) -> usize {
        self.models.len() * self.bandwidth_scales.len() * self.partitions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn validate(&self) -> Result<()> {
        self.accel.validate()?;
        if self.models.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no models".into()));
        }
        if self.partitions.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no partition counts".into()));
        }
        if self.bandwidth_scales.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no bandwidth scales".into()));
        }
        for m in &self.models {
            crate::model::by_name(m)?;
        }
        for &s in &self.bandwidth_scales {
            if !(s.is_finite() && s > 0.0) {
                return Err(Error::InvalidConfig(format!("bandwidth scale {s} must be > 0")));
            }
        }
        for &n in &self.partitions {
            if n == 0 {
                return Err(Error::InvalidConfig("partition count 0 in sweep grid".into()));
            }
        }
        if self.steady_batches == 0 {
            return Err(Error::InvalidConfig("steady_batches must be > 0".into()));
        }
        if self.trace_samples == 0 {
            return Err(Error::InvalidConfig("trace_samples must be > 0".into()));
        }
        Ok(())
    }

    /// Enumerate all scenarios in report order.
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        let mut id = 0;
        for model in &self.models {
            for &scale in &self.bandwidth_scales {
                for &n in &self.partitions {
                    out.push(Scenario {
                        id,
                        model: model.clone(),
                        partitions: n,
                        bandwidth_scale: scale,
                        steady_batches: self.steady_batches,
                    });
                    id += 1;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knl() -> AcceleratorConfig {
        AcceleratorConfig::knl_7210()
    }

    #[test]
    fn default_grid_covers_the_zoo() {
        let g = SweepGrid::new(&knl());
        assert_eq!(g.len(), 5 * 5);
        g.validate().unwrap();
        let sc = g.scenarios();
        assert_eq!(sc.len(), g.len());
        // Ids are the enumeration order.
        for (i, s) in sc.iter().enumerate() {
            assert_eq!(s.id, i);
        }
        // Model-major: first block is all-vgg16.
        assert!(sc[..5].iter().all(|s| s.model == "vgg16"));
        assert_eq!(sc[0].partitions, 1);
        assert_eq!(sc[4].partitions, 16);
    }

    #[test]
    fn bandwidth_scale_modifies_accel_only() {
        let s = Scenario {
            id: 0,
            model: "resnet50".into(),
            partitions: 2,
            bandwidth_scale: 0.5,
            steady_batches: 4,
        };
        let base = knl();
        let a = s.accel(&base);
        assert!((a.mem_bw.0 - base.mem_bw.0 * 0.5).abs() < 1e-6);
        assert_eq!(a.cores, base.cores);
        assert!(s.label().contains("resnet50@2p"));
    }

    #[test]
    fn validation_rejects_bad_grids() {
        assert!(SweepGrid::new(&knl()).models(Vec::<String>::new()).validate().is_err());
        assert!(SweepGrid::new(&knl()).models(vec!["not_a_model"]).validate().is_err());
        assert!(SweepGrid::new(&knl()).partitions(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).partitions(vec![0]).validate().is_err());
        assert!(SweepGrid::new(&knl()).bandwidth_scales(vec![-1.0]).validate().is_err());
        assert!(SweepGrid::new(&knl()).bandwidth_scales(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).steady_batches(0).validate().is_err());
        assert!(SweepGrid::new(&knl()).trace_samples(0).validate().is_err());
        SweepGrid::new(&knl()).validate().unwrap();
    }
}
