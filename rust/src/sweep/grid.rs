//! Scenario grids: the cartesian product of models × bandwidth
//! configurations × stagger policies × arrival rates × partition counts a
//! sweep explores.

use crate::config::AcceleratorConfig;
use crate::error::{Error, Result};
use crate::serve::{ServeConfig, TenantSpec};
use crate::shaping::StaggerPolicy;
use crate::util::units::BytesPerS;

/// The model zoo a default sweep covers (the paper's three evaluation
/// networks plus AlexNet and the e2e TinyCNN).
pub const DEFAULT_SWEEP_MODELS: [&str; 5] = ["vgg16", "googlenet", "resnet50", "alexnet", "tiny"];

/// One point of the sweep grid. `id` is the point's index in the grid's
/// enumeration order and the key that makes parallel execution
/// order-independent: results are always reported in `id` order, no
/// matter which worker thread computed them.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub id: usize,
    pub model: String,
    pub partitions: usize,
    /// Multiplier on the accelerator's sustained memory bandwidth —
    /// sweeping it explores how the shaping win moves with the
    /// compute/bandwidth balance (cf. the unlimited-BW ablation).
    pub bandwidth_scale: f64,
    /// How the asynchronous partitions are de-phased (offline rows) or
    /// start-gated (serve rows).
    pub stagger: StaggerPolicy,
    /// Offered load in requests/second; 0.0 means the offline
    /// fixed-batch mode (the paper's original experiment).
    pub arrival_rate: f64,
    /// Serve rows: per-partition queue bound (0 = unbounded). Always 0
    /// for offline rows — the axis only multiplies serving scenarios.
    pub queue_cap: usize,
    /// Serve rows: latency deadline in ms (0 = none). Always 0 offline.
    pub slo_ms: f64,
    pub steady_batches: usize,
    /// Mixed-tenant rows: the `model:share:rate,...` tenant spec. `None`
    /// is the classic single-model scenario; `Some` rows run the
    /// co-scheduled multi-tenant simulator against a time-shared
    /// baseline at identical offered load (`model` is `"mixed"`,
    /// `partitions` the tenant count).
    pub tenants: Option<String>,
}

impl Scenario {
    /// Whether this point is a serving run (vs the offline batch mode).
    pub fn is_serve(&self) -> bool {
        self.arrival_rate > 0.0
    }

    /// Human-readable tag used in reports and logs.
    pub fn label(&self) -> String {
        let mut s = match &self.tenants {
            Some(spec) => format!("mixed[{spec}]/bw{:.2}x", self.bandwidth_scale),
            None => format!("{}@{}p/bw{:.2}x", self.model, self.partitions, self.bandwidth_scale),
        };
        if self.stagger != StaggerPolicy::UniformPhase {
            s.push_str(&format!("/{}", self.stagger.name()));
        }
        if self.is_serve() {
            s.push_str(&format!("/λ{:.0}", self.arrival_rate));
            if self.queue_cap > 0 {
                s.push_str(&format!("/cap{}", self.queue_cap));
            }
            if self.slo_ms > 0.0 {
                s.push_str(&format!("/slo{:.0}", self.slo_ms));
            }
        }
        s
    }

    /// The accelerator this scenario runs on: `base` with the bandwidth
    /// knob scaled.
    pub fn accel(&self, base: &AcceleratorConfig) -> AcceleratorConfig {
        let mut a = base.clone();
        a.mem_bw = BytesPerS(base.mem_bw.0 * self.bandwidth_scale);
        a
    }
}

/// Builder for a sweep grid. `scenarios()` enumerates the cartesian
/// product model-major, then bandwidth scale, then stagger policy, then
/// arrival rate, then partition count — the order every report uses.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub accel: AcceleratorConfig,
    pub models: Vec<String>,
    pub partitions: Vec<usize>,
    pub bandwidth_scales: Vec<f64>,
    /// Stagger policies to sweep; defaults to the paper's steady-state
    /// [`StaggerPolicy::UniformPhase`] only.
    pub stagger_policies: Vec<StaggerPolicy>,
    /// Arrival-rate axis; 0.0 (the default sole entry) is the offline
    /// batch mode, any positive rate adds a serving scenario.
    pub arrival_rates: Vec<f64>,
    pub steady_batches: usize,
    /// Shared serving configuration for serve scenarios: arrival window,
    /// stream seed and batch hold timeout come from here (the grid's own
    /// axes override its `partitions`/`rates`/overload knobs per
    /// scenario).
    pub serve: ServeConfig,
    /// Queue-bound axis for serve scenarios (0 = unbounded). Like the
    /// other axes this multiplies the grid — a cap × SLO sub-grid per
    /// (model, bw, stagger, rate) charts the goodput/drop trade-off
    /// surface. Offline rows ignore it.
    pub serve_queue_caps: Vec<usize>,
    /// Latency-deadline axis for serve scenarios, ms (0 = none).
    pub serve_slo_ms: Vec<f64>,
    /// Mixed-tenant scenario axis: each entry is a `model:share:rate,...`
    /// tenant spec run once per bandwidth scale (co-scheduled vs its own
    /// time-shared baseline). Empty by default.
    pub mixed_tenants: Vec<String>,
    pub trace_samples: usize,
}

impl SweepGrid {
    pub fn new(accel: &AcceleratorConfig) -> Self {
        Self {
            accel: accel.clone(),
            models: DEFAULT_SWEEP_MODELS.iter().map(|s| s.to_string()).collect(),
            partitions: vec![1, 2, 4, 8, 16],
            bandwidth_scales: vec![1.0],
            stagger_policies: vec![StaggerPolicy::UniformPhase],
            arrival_rates: vec![0.0],
            steady_batches: 6,
            serve: ServeConfig { duration_s: 0.25, ..ServeConfig::default() },
            serve_queue_caps: vec![0],
            serve_slo_ms: vec![0.0],
            mixed_tenants: Vec::new(),
            trace_samples: 400,
        }
    }

    pub fn models<S: Into<String>>(mut self, models: Vec<S>) -> Self {
        self.models = models.into_iter().map(Into::into).collect();
        self
    }

    pub fn partitions(mut self, partitions: Vec<usize>) -> Self {
        self.partitions = partitions;
        self
    }

    pub fn bandwidth_scales(mut self, scales: Vec<f64>) -> Self {
        self.bandwidth_scales = scales;
        self
    }

    pub fn stagger_policies(mut self, policies: Vec<StaggerPolicy>) -> Self {
        self.stagger_policies = policies;
        self
    }

    pub fn arrival_rates(mut self, rates: Vec<f64>) -> Self {
        self.arrival_rates = rates;
        self
    }

    pub fn steady_batches(mut self, batches: usize) -> Self {
        self.steady_batches = batches;
        self
    }

    /// Shim for [`ServeConfig::duration_s`] on the embedded serve config.
    pub fn serve_duration(mut self, seconds: f64) -> Self {
        self.serve.duration_s = seconds;
        self
    }

    /// Shim for [`ServeConfig::seed`] on the embedded serve config.
    pub fn serve_seed(mut self, seed: u64) -> Self {
        self.serve.seed = seed;
        self
    }

    /// Monte-Carlo replications per serve scenario (≥ 1; offline rows
    /// are deterministic and always run once). See
    /// [`super::ReplicationPlan`].
    pub fn serve_replications(mut self, n: usize) -> Self {
        self.serve.replications = n;
        self
    }

    /// Interval coverage for replication folds — shim for
    /// [`ServeConfig::confidence`] on the embedded serve config.
    pub fn serve_confidence(mut self, confidence: crate::util::stats::Confidence) -> Self {
        self.serve.confidence = confidence;
        self
    }

    /// Bound each serve-scenario partition queue (0 = unbounded) —
    /// single-value convenience over [`Self::serve_queue_caps`].
    pub fn serve_queue_cap(mut self, cap: usize) -> Self {
        self.serve_queue_caps = vec![cap];
        self
    }

    /// The queue-bound *axis*: one serve scenario per cap (0 = unbounded).
    pub fn serve_queue_caps(mut self, caps: Vec<usize>) -> Self {
        self.serve_queue_caps = caps;
        self
    }

    /// Latency deadline for serve scenarios in ms (0 = none) —
    /// single-value convenience over [`Self::serve_slo_ms_axis`].
    pub fn serve_slo_ms(mut self, ms: f64) -> Self {
        self.serve_slo_ms = vec![ms];
        self
    }

    /// The latency-deadline *axis*: one serve scenario per SLO (ms,
    /// 0 = none).
    pub fn serve_slo_ms_axis(mut self, ms: Vec<f64>) -> Self {
        self.serve_slo_ms = ms;
        self
    }

    /// Batch hold timeout for serve scenarios in ms (0 = on idle). Shim
    /// for [`ServeConfig::batch_timeout_ms`] on the embedded serve config.
    pub fn serve_batch_timeout_ms(mut self, ms: f64) -> Self {
        self.serve.batch_timeout_ms = ms;
        self
    }

    /// The mixed-tenant axis: each `model:share:rate,...` spec adds one
    /// co-scheduled multi-tenant scenario per bandwidth scale, compared
    /// against its own time-shared baseline at identical offered load.
    pub fn mixed_tenants<S: Into<String>>(mut self, specs: Vec<S>) -> Self {
        self.mixed_tenants = specs.into_iter().map(Into::into).collect();
        self
    }

    pub fn trace_samples(mut self, samples: usize) -> Self {
        self.trace_samples = samples;
        self
    }

    /// Number of scenarios the grid enumerates. The cap × SLO sub-grid
    /// applies to serving rates only — offline rows (rate 0) have no
    /// queues to bound.
    pub fn len(&self) -> usize {
        let serve_rates = self.arrival_rates.iter().filter(|&&r| r > 0.0).count();
        let offline_rates = self.arrival_rates.len() - serve_rates;
        let per_rate = offline_rates
            + serve_rates * self.serve_queue_caps.len().max(1) * self.serve_slo_ms.len().max(1);
        self.models.len()
            * self.bandwidth_scales.len()
            * self.stagger_policies.len()
            * per_rate
            * self.partitions.len()
            + self.mixed_tenants.len() * self.bandwidth_scales.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn validate(&self) -> Result<()> {
        self.accel.validate()?;
        if self.models.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no models".into()));
        }
        if self.partitions.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no partition counts".into()));
        }
        if self.bandwidth_scales.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no bandwidth scales".into()));
        }
        if self.stagger_policies.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no stagger policies".into()));
        }
        if self.arrival_rates.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no arrival rates".into()));
        }
        for m in &self.models {
            crate::model::by_name(m)?;
        }
        for &s in &self.bandwidth_scales {
            if !(s.is_finite() && s > 0.0) {
                return Err(Error::InvalidConfig(format!("bandwidth scale {s} must be > 0")));
            }
        }
        for &r in &self.arrival_rates {
            if !(r.is_finite() && r >= 0.0) {
                return Err(Error::InvalidConfig(format!(
                    "arrival rate {r} must be ≥ 0 (0 = offline batch mode)"
                )));
            }
        }
        for &n in &self.partitions {
            if n == 0 {
                return Err(Error::InvalidConfig("partition count 0 in sweep grid".into()));
            }
        }
        if self.steady_batches == 0 {
            return Err(Error::InvalidConfig("steady_batches must be > 0".into()));
        }
        if !(self.serve.duration_s.is_finite() && self.serve.duration_s > 0.0) {
            return Err(Error::InvalidConfig(format!(
                "serve duration {} must be > 0",
                self.serve.duration_s
            )));
        }
        self.serve.validate()?;
        if self.serve_queue_caps.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no serve queue caps".into()));
        }
        if self.serve_slo_ms.is_empty() {
            return Err(Error::InvalidConfig("sweep grid has no serve SLOs".into()));
        }
        for &ms in &self.serve_slo_ms {
            if !(ms.is_finite() && ms >= 0.0) {
                return Err(Error::InvalidConfig(format!(
                    "serve SLO {ms} must be finite and >= 0 ms"
                )));
            }
        }
        if !(self.serve.batch_timeout_ms.is_finite() && self.serve.batch_timeout_ms >= 0.0) {
            return Err(Error::InvalidConfig(format!(
                "serve batch timeout {} must be finite and >= 0 ms",
                self.serve.batch_timeout_ms
            )));
        }
        if self.trace_samples == 0 {
            return Err(Error::InvalidConfig("trace_samples must be > 0".into()));
        }
        for spec in &self.mixed_tenants {
            TenantSpec::parse_list(spec)?;
        }
        Ok(())
    }

    /// Enumerate all scenarios in report order. Serving rates fan out
    /// over the cap × SLO sub-grid (cap-major, then SLO, then partition
    /// count); offline rows carry the 0/0 sentinel.
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        let mut id = 0;
        for model in &self.models {
            for &scale in &self.bandwidth_scales {
                for &stagger in &self.stagger_policies {
                    for &rate in &self.arrival_rates {
                        let combos: Vec<(usize, f64)> = if rate > 0.0 {
                            self.serve_queue_caps
                                .iter()
                                .flat_map(|&c| self.serve_slo_ms.iter().map(move |&s| (c, s)))
                                .collect()
                        } else {
                            vec![(0, 0.0)]
                        };
                        for (cap, slo) in combos {
                            for &n in &self.partitions {
                                out.push(Scenario {
                                    id,
                                    model: model.clone(),
                                    partitions: n,
                                    bandwidth_scale: scale,
                                    stagger,
                                    arrival_rate: rate,
                                    queue_cap: cap,
                                    slo_ms: slo,
                                    steady_batches: self.steady_batches,
                                    tenants: None,
                                });
                                id += 1;
                            }
                        }
                    }
                }
            }
        }
        // Mixed-tenant rows ride at the end of the grid, one per
        // (bandwidth scale, tenant spec). `partitions` is the tenant
        // count; `arrival_rate` the summed offered rate, so serve-row
        // handling (labels, latency columns) applies.
        for &scale in &self.bandwidth_scales {
            for spec in &self.mixed_tenants {
                let (count, rate) = mixed_axis_info(spec);
                out.push(Scenario {
                    id,
                    model: "mixed".into(),
                    partitions: count.max(1),
                    bandwidth_scale: scale,
                    stagger: StaggerPolicy::UniformPhase,
                    arrival_rate: rate,
                    queue_cap: 0,
                    slo_ms: 0.0,
                    steady_batches: self.steady_batches,
                    tenants: Some(spec.clone()),
                });
                id += 1;
            }
        }
        out
    }
}

/// Tenant count and summed offered rate of a `model:share:rate,...`
/// spec, parsed leniently (the strict check lives in `validate`).
fn mixed_axis_info(spec: &str) -> (usize, f64) {
    let mut count = 0usize;
    let mut rate = 0.0f64;
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        count += 1;
        if let Some(r) = part.split(':').nth(2).and_then(|s| s.trim().parse::<f64>().ok()) {
            rate += r;
        }
    }
    (count, rate)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knl() -> AcceleratorConfig {
        AcceleratorConfig::knl_7210()
    }

    #[test]
    fn default_grid_covers_the_zoo() {
        let g = SweepGrid::new(&knl());
        assert_eq!(g.len(), 5 * 5);
        g.validate().unwrap();
        let sc = g.scenarios();
        assert_eq!(sc.len(), g.len());
        // Ids are the enumeration order.
        for (i, s) in sc.iter().enumerate() {
            assert_eq!(s.id, i);
        }
        // Model-major: first block is all-vgg16, offline by default.
        assert!(sc[..5].iter().all(|s| s.model == "vgg16" && !s.is_serve()));
        assert_eq!(sc[0].partitions, 1);
        assert_eq!(sc[4].partitions, 16);
    }

    #[test]
    fn stagger_and_rate_axes_multiply_the_grid() {
        let g = SweepGrid::new(&knl())
            .models(vec!["resnet50"])
            .partitions(vec![1, 4])
            .stagger_policies(vec![StaggerPolicy::None, StaggerPolicy::UniformPhase])
            .arrival_rates(vec![0.0, 500.0]);
        assert_eq!(g.len(), 8); // 1 model × 1 bw × 2 staggers × 2 rates × 2 ns
        g.validate().unwrap();
        let sc = g.scenarios();
        // Stagger-major over rate over partitions.
        assert_eq!(sc[0].stagger, StaggerPolicy::None);
        assert!(!sc[0].is_serve());
        assert!(sc[2].is_serve());
        assert_eq!(sc[2].arrival_rate, 500.0);
        assert_eq!(sc[4].stagger, StaggerPolicy::UniformPhase);
        // Serve + non-default-stagger rows advertise it in the label.
        assert!(sc[2].label().contains("/none"));
        assert!(sc[2].label().contains("/λ500"));
        assert!(!sc[4].label().contains("/uniform_phase"));
    }

    #[test]
    fn serve_cap_and_slo_axes_multiply_serving_rows_only() {
        let g = SweepGrid::new(&knl())
            .models(vec!["resnet50"])
            .partitions(vec![1, 2])
            .arrival_rates(vec![0.0, 500.0])
            .serve_queue_caps(vec![0, 8])
            .serve_slo_ms_axis(vec![0.0, 50.0]);
        // Offline: 2 rows; serve: 2 caps × 2 SLOs × 2 ns = 8 rows.
        assert_eq!(g.len(), 10);
        g.validate().unwrap();
        let sc = g.scenarios();
        assert_eq!(sc.len(), 10);
        for (i, s) in sc.iter().enumerate() {
            assert_eq!(s.id, i);
        }
        // Offline rows carry the 0/0 sentinel.
        assert!(sc[..2].iter().all(|s| !s.is_serve() && s.queue_cap == 0 && s.slo_ms == 0.0));
        // Serve rows: cap-major, then SLO, then partitions.
        assert_eq!((sc[2].queue_cap, sc[2].slo_ms, sc[2].partitions), (0, 0.0, 1));
        assert_eq!((sc[3].queue_cap, sc[3].slo_ms, sc[3].partitions), (0, 0.0, 2));
        assert_eq!((sc[4].queue_cap, sc[4].slo_ms), (0, 50.0));
        assert_eq!((sc[6].queue_cap, sc[6].slo_ms), (8, 0.0));
        assert_eq!((sc[8].queue_cap, sc[8].slo_ms), (8, 50.0));
        // Labels advertise the overload knobs on serve rows only.
        assert!(sc[8].label().contains("/cap8"));
        assert!(sc[8].label().contains("/slo50"));
        assert!(!sc[2].label().contains("/cap"));
        // The single-value builders stay usable.
        let single = SweepGrid::new(&knl()).serve_queue_cap(4).serve_slo_ms(25.0);
        assert_eq!(single.serve_queue_caps, vec![4]);
        assert_eq!(single.serve_slo_ms, vec![25.0]);
        // Validation rejects empty or malformed axes.
        assert!(SweepGrid::new(&knl()).serve_queue_caps(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).serve_slo_ms_axis(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).serve_slo_ms_axis(vec![-1.0]).validate().is_err());
        assert!(SweepGrid::new(&knl()).serve_slo_ms_axis(vec![f64::NAN]).validate().is_err());
    }

    #[test]
    fn bandwidth_scale_modifies_accel_only() {
        let s = Scenario {
            id: 0,
            model: "resnet50".into(),
            partitions: 2,
            bandwidth_scale: 0.5,
            stagger: StaggerPolicy::UniformPhase,
            arrival_rate: 0.0,
            queue_cap: 0,
            slo_ms: 0.0,
            steady_batches: 4,
            tenants: None,
        };
        let base = knl();
        let a = s.accel(&base);
        assert!((a.mem_bw.0 - base.mem_bw.0 * 0.5).abs() < 1e-6);
        assert_eq!(a.cores, base.cores);
        assert!(s.label().contains("resnet50@2p"));
    }

    #[test]
    fn mixed_tenant_axis_appends_one_row_per_bw_scale() {
        let g = SweepGrid::new(&knl())
            .models(vec!["tiny"])
            .partitions(vec![1, 2])
            .bandwidth_scales(vec![1.0, 0.75])
            .mixed_tenants(vec!["resnet50:0.6:300,vgg16:0.4:120"]);
        // 1 model × 2 bw × 2 n = 4 classic rows + 2 mixed rows.
        assert_eq!(g.len(), 6);
        g.validate().unwrap();
        let sc = g.scenarios();
        assert_eq!(sc.len(), 6);
        for (i, s) in sc.iter().enumerate() {
            assert_eq!(s.id, i);
        }
        assert!(sc[..4].iter().all(|s| s.tenants.is_none()));
        let mixed = &sc[4];
        assert_eq!(mixed.model, "mixed");
        assert_eq!(mixed.partitions, 2, "tenant count");
        assert!((mixed.arrival_rate - 420.0).abs() < 1e-9, "summed offered rate");
        assert!(mixed.is_serve());
        assert_eq!(mixed.tenants.as_deref(), Some("resnet50:0.6:300,vgg16:0.4:120"));
        assert!(mixed.label().starts_with("mixed[resnet50:0.6:300"), "{}", mixed.label());
        assert!(mixed.label().contains("/λ420"));
        assert_eq!(sc[5].bandwidth_scale, 0.75);
        // A malformed spec is a validation error, not a runtime panic.
        let bad = SweepGrid::new(&knl()).mixed_tenants(vec!["resnet50:0.6"]);
        assert!(bad.validate().is_err());
        let bad = SweepGrid::new(&knl()).mixed_tenants(vec!["nosuchmodel:1:100"]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_grids() {
        assert!(SweepGrid::new(&knl()).models(Vec::<String>::new()).validate().is_err());
        assert!(SweepGrid::new(&knl()).models(vec!["not_a_model"]).validate().is_err());
        assert!(SweepGrid::new(&knl()).partitions(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).partitions(vec![0]).validate().is_err());
        assert!(SweepGrid::new(&knl()).bandwidth_scales(vec![-1.0]).validate().is_err());
        assert!(SweepGrid::new(&knl()).bandwidth_scales(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).stagger_policies(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).arrival_rates(vec![]).validate().is_err());
        assert!(SweepGrid::new(&knl()).arrival_rates(vec![-2.0]).validate().is_err());
        assert!(SweepGrid::new(&knl()).arrival_rates(vec![f64::NAN]).validate().is_err());
        assert!(SweepGrid::new(&knl()).serve_duration(0.0).validate().is_err());
        assert!(SweepGrid::new(&knl()).serve_slo_ms(f64::NAN).validate().is_err());
        assert!(SweepGrid::new(&knl()).serve_slo_ms(-1.0).validate().is_err());
        assert!(SweepGrid::new(&knl()).serve_batch_timeout_ms(-2.0).validate().is_err());
        assert!(SweepGrid::new(&knl()).steady_batches(0).validate().is_err());
        assert!(SweepGrid::new(&knl()).trace_samples(0).validate().is_err());
        SweepGrid::new(&knl()).validate().unwrap();
    }
}
