//! Parallel sweep execution.
//!
//! The fluid simulator is pure and `Send`-friendly, and every sweep
//! scenario is independent, so a grid is embarrassingly parallel. The
//! runner fans scenarios out over a pool of `std::thread` workers in two
//! phases:
//!
//! 1. **baselines** — one 1-partition run per distinct
//!    (model, bandwidth-scale, arrival-rate, queue-cap, SLO) tuple: the
//!    synchronous offline baseline for rate 0, the unpartitioned serving
//!    run for positive rates — shared by every partition count and
//!    stagger policy of that tuple;
//! 2. **scenarios** — each grid point runs against its precomputed
//!    baseline.
//!
//! Determinism: workers pull indices from an atomic counter but write
//! results into per-index slots, and the report is assembled in index
//! order — so the aggregated output is byte-identical whether the pool
//! has 1 thread or N. Errors are deterministic too: the error attached
//! to the lowest index wins.
//!
//! With `serve.replications > 1` every serve scenario (and its serve
//! baseline) repeats once per [`super::ReplicationPlan`] seed through
//! the same pool — replication r always compares against the
//! replication-r baseline, so the relative-performance CI measures the
//! partitioning effect, not seed luck. Offline rows are deterministic
//! and keep running once.

use super::grid::{Scenario, SweepGrid};
use super::replicate::ReplicationPlan;
use super::report::{ScenarioOutcome, ScenarioStatus, SweepMetrics, SweepReport};
use crate::error::{Error, Result};
use crate::model::Graph;
use crate::serve::{
    ArrivalProcess, MultiTenantSimulator, ServeOutcome, ServeSimulator, TenantMode, TenantSpec,
};
use crate::shaping::{PartitionExperiment, ShapingAnalysis, StaggerPolicy};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

/// Deterministic parallel map: applies `f` to every item on `threads`
/// workers and returns the results in item order. The first error in
/// item order (not completion order) is the one reported. Shared by the
/// sweep runner and the serve-curve experiment.
pub(crate) fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> Result<R> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.clamp(1, n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // staticcheck: allow(R3) -- a poisoned slot means a worker panicked
                *slots[i].lock().expect("sweep slot poisoned") = Some(r);
            });
        }
    });
    let mut out = Vec::with_capacity(n);
    for slot in slots {
        // staticcheck: allow(R3) -- a poisoned slot means a worker panicked
        match slot.into_inner().expect("sweep slot poisoned") {
            Some(Ok(r)) => out.push(r),
            Some(Err(e)) => return Err(e),
            None => {
                return Err(Error::SimInvariant(
                    "sweep worker pool dropped a scenario".into(),
                ))
            }
        }
    }
    Ok(out)
}

/// A precomputed 1-partition baseline: offline shaping analysis for
/// batch-mode scenarios, one full serving outcome *per replication*
/// (replication-index order) for serve scenarios.
enum Baseline {
    Offline(ShapingAnalysis),
    Serve(Vec<ServeOutcome>),
}

/// One baseline task's result before regrouping into [`Baseline`].
enum BaselineRun {
    Offline(ShapingAnalysis),
    Serve(Box<ServeOutcome>),
}

/// Runs a [`SweepGrid`] across a worker pool and aggregates the ranked
/// [`SweepReport`].
#[derive(Debug, Clone)]
pub struct SweepRunner {
    grid: SweepGrid,
    threads: usize,
}

impl SweepRunner {
    pub fn new(grid: SweepGrid) -> Self {
        Self { grid, threads: 0 }
    }

    /// Worker thread count; 0 (the default) uses the host's available
    /// parallelism.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// The pool size `run` will actually use.
    pub fn effective_threads(&self) -> usize {
        let hw = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let t = if self.threads == 0 { hw } else { self.threads };
        t.clamp(1, self.grid.len().max(1))
    }

    fn experiment(&self, scenario: &Scenario, graph: &Graph) -> PartitionExperiment {
        PartitionExperiment::new(&scenario.accel(&self.grid.accel), graph)
            .partitions(scenario.partitions)
            .steady_batches(scenario.steady_batches)
            .trace_samples(self.grid.trace_samples)
            .stagger(scenario.stagger)
    }

    fn serve_sim(&self, scenario: &Scenario, graph: &Graph, seed: u64) -> ServeSimulator {
        ServeSimulator::new(&scenario.accel(&self.grid.accel), graph)
            .partitions(scenario.partitions)
            .arrival(ArrivalProcess::poisson(scenario.arrival_rate))
            .duration(self.grid.serve.duration_s)
            .seed(seed)
            .stagger(scenario.stagger)
            .queue_cap(scenario.queue_cap)
            .slo_ms(scenario.slo_ms)
            .batch_timeout_ms(self.grid.serve.batch_timeout_ms)
            .trace_samples(self.grid.trace_samples)
    }

    /// The multi-tenant simulator for a mixed scenario — co-scheduled
    /// for the grid row, time-shared for its baseline.
    fn mixed_sim(
        &self,
        scenario: &Scenario,
        spec: &str,
        mode: TenantMode,
        seed: u64,
    ) -> Result<MultiTenantSimulator> {
        let specs = TenantSpec::parse_list(spec)?;
        Ok(MultiTenantSimulator::new(&scenario.accel(&self.grid.accel), specs)
            .duration(self.grid.serve.duration_s)
            .seed(seed)
            .stagger(scenario.stagger)
            .batch_timeout_ms(self.grid.serve.batch_timeout_ms)
            .mode(mode)
            .trace_samples(self.grid.trace_samples))
    }

    /// Execute the full grid and aggregate the report.
    pub fn run(&self) -> Result<SweepReport> {
        self.grid.validate()?;
        let threads = self.effective_threads();

        // Graphs are immutable once built; resolve each model once and
        // share references across the pool.
        let mut graphs: BTreeMap<String, Graph> = BTreeMap::new();
        for m in &self.grid.models {
            graphs.insert(m.clone(), crate::model::by_name(m)?);
        }

        // Phase 1: one 1-partition baseline per distinct
        // (model, bandwidth scale, arrival rate, queue cap, SLO) — the
        // overload knobs shape the baseline run too, so each cap × SLO
        // sub-grid point compares against its own 1-partition machine.
        type Key = (String, u64, u64, usize, u64, String);
        // Dedup by bit pattern — the same key the baseline map uses
        // (f64 == would merge 0.0 and -0.0 here but not there). Mixed
        // rows key on their tenant spec as well.
        let mut seen: BTreeSet<Key> = BTreeSet::new();
        let mut keys: Vec<(String, f64, f64, usize, f64, String)> = Vec::new();
        for sc in self.grid.scenarios() {
            let tenants = sc.tenants.clone().unwrap_or_default();
            let key = (
                sc.model.clone(),
                sc.bandwidth_scale.to_bits(),
                sc.arrival_rate.to_bits(),
                sc.queue_cap,
                sc.slo_ms.to_bits(),
                tenants.clone(),
            );
            if seen.insert(key) {
                keys.push((
                    sc.model,
                    sc.bandwidth_scale,
                    sc.arrival_rate,
                    sc.queue_cap,
                    sc.slo_ms,
                    tenants,
                ));
            }
        }
        // Replication fan-out: serve baselines and serve scenarios run
        // once per plan seed; offline rows are deterministic and run
        // once. Tasks are key-major / replication-minor, so regrouping
        // is a chunked fold and replication 0 stays the headline.
        let plan = self.grid.serve.replication_plan();
        let seeds = plan.seeds();
        let reps = seeds.len();
        // How many times a row with these axes runs: serve and mixed
        // rows once per seed, offline rows once.
        let runs_of = |rate: f64, tenants: &str| -> usize {
            if rate > 0.0 || !tenants.is_empty() {
                reps
            } else {
                1
            }
        };
        let mut base_tasks: Vec<(usize, u64)> = Vec::new();
        for (ki, (_, _, rate, _, _, tenants)) in keys.iter().enumerate() {
            for &seed in seeds.iter().take(runs_of(*rate, tenants)) {
                base_tasks.push((ki, seed));
            }
        }
        let base_runs = parallel_map(&base_tasks, threads, |&(ki, seed)| {
            let (model, scale, rate, cap, slo, tenants) = &keys[ki];
            let probe = Scenario {
                id: 0,
                model: model.clone(),
                partitions: 1,
                bandwidth_scale: *scale,
                stagger: StaggerPolicy::None,
                arrival_rate: *rate,
                queue_cap: *cap,
                slo_ms: *slo,
                steady_batches: self.grid.steady_batches,
                tenants: (!tenants.is_empty()).then(|| tenants.clone()),
            };
            if !tenants.is_empty() {
                // The mixed row's reference point: the same tenants
                // time-sharing the whole machine.
                let out = self.mixed_sim(&probe, tenants, TenantMode::TimeShared, seed)?.run()?;
                Ok(BaselineRun::Serve(Box::new(out.aggregate)))
            } else if probe.is_serve() {
                let out = self.serve_sim(&probe, &graphs[model], seed).run()?;
                Ok(BaselineRun::Serve(Box::new(out)))
            } else {
                Ok(BaselineRun::Offline(self.experiment(&probe, &graphs[model]).run_baseline()?))
            }
        })?;
        let mut baselines: BTreeMap<Key, Baseline> = BTreeMap::new();
        for (&(ki, _), run) in base_tasks.iter().zip(base_runs) {
            let (m, s, r, c, d, t) = &keys[ki];
            let key = (m.clone(), s.to_bits(), r.to_bits(), *c, d.to_bits(), t.clone());
            match run {
                BaselineRun::Offline(a) => {
                    baselines.insert(key, Baseline::Offline(a));
                }
                BaselineRun::Serve(o) => match baselines
                    .entry(key)
                    .or_insert_with(|| Baseline::Serve(Vec::with_capacity(reps)))
                {
                    Baseline::Serve(v) => v.push(*o),
                    Baseline::Offline(_) => {
                        return Err(Error::SimInvariant("sweep baseline kind mismatch".into()))
                    }
                },
            }
        }

        // Phase 2: every (scenario, replication) against its same-seed
        // shared baseline.
        let scenarios = self.grid.scenarios();
        let mut tasks: Vec<(usize, usize, u64)> = Vec::new();
        for (si, sc) in scenarios.iter().enumerate() {
            let n = runs_of(sc.arrival_rate, sc.tenants.as_deref().unwrap_or(""));
            for (rep, &seed) in seeds.iter().take(n).enumerate() {
                tasks.push((si, rep, seed));
            }
        }
        let statuses = parallel_map(&tasks, threads, |&(si, rep, seed)| {
            let sc = &scenarios[si];
            let key = (
                sc.model.clone(),
                sc.bandwidth_scale.to_bits(),
                sc.arrival_rate.to_bits(),
                sc.queue_cap,
                sc.slo_ms.to_bits(),
                sc.tenants.clone().unwrap_or_default(),
            );
            // Mixed rows: co-scheduled tenants vs the time-shared
            // baseline at identical offered load (and seed).
            if let Some(spec) = &sc.tenants {
                let Baseline::Serve(bases) = &baselines[&key] else {
                    return Err(Error::SimInvariant("mixed baseline kind mismatch".into()));
                };
                return match self.mixed_sim(sc, spec, TenantMode::Coscheduled, seed)?.run() {
                    Ok(out) => {
                        let m = SweepMetrics::from_serve(&out.aggregate, &bases[rep]);
                        Ok(ScenarioStatus::Completed(m))
                    }
                    Err(Error::InfeasiblePartitioning(why)) => Ok(ScenarioStatus::Infeasible(why)),
                    Err(e) => Err(e),
                };
            }
            // A 1-partition scenario IS its baseline only when the stagger
            // is a no-op at n = 1 (None/UniformPhase both degenerate to no
            // offset; RandomDelay still delays the single partition).
            let is_own_baseline = sc.partitions == 1
                && !matches!(sc.stagger, StaggerPolicy::RandomDelay { .. });
            match (&baselines[&key], sc.is_serve()) {
                (Baseline::Serve(bases), true) => {
                    let base = &bases[rep];
                    if is_own_baseline {
                        return Ok(ScenarioStatus::Completed(SweepMetrics::serve_baseline_row(
                            base,
                        )));
                    }
                    match self.serve_sim(sc, &graphs[&sc.model], seed).run() {
                        Ok(out) => {
                            Ok(ScenarioStatus::Completed(SweepMetrics::from_serve(&out, base)))
                        }
                        Err(Error::InfeasiblePartitioning(why)) => {
                            Ok(ScenarioStatus::Infeasible(why))
                        }
                        Err(e) => Err(e),
                    }
                }
                (Baseline::Offline(base), false) => {
                    if is_own_baseline {
                        return Ok(ScenarioStatus::Completed(SweepMetrics::baseline_row(base)));
                    }
                    match self.experiment(sc, &graphs[&sc.model]).run_against(base) {
                        Ok(report) => {
                            Ok(ScenarioStatus::Completed(SweepMetrics::from_report(&report)))
                        }
                        Err(Error::InfeasiblePartitioning(why)) => {
                            Ok(ScenarioStatus::Infeasible(why))
                        }
                        Err(e) => Err(e),
                    }
                }
                _ => Err(Error::SimInvariant("sweep baseline kind mismatch".into())),
            }
        })?;

        // Regroup per scenario: replication 0 is the headline row;
        // replicated serve rows fold their per-replication metrics into
        // mean ± CI statistics (id-keyed, thread-count independent).
        let mut statuses = statuses.into_iter();
        let outcomes = scenarios
            .into_iter()
            .map(|scenario| {
                let tenants = scenario.tenants.as_deref().unwrap_or("");
                let n = runs_of(scenario.arrival_rate, tenants);
                let group: Vec<ScenarioStatus> = statuses.by_ref().take(n).collect();
                let mut status = group[0].clone();
                if n > 1 {
                    if let ScenarioStatus::Completed(head) = &mut status {
                        let per_rep: Vec<SweepMetrics> = group
                            .iter()
                            .filter_map(|s| match s {
                                ScenarioStatus::Completed(m) => Some(*m),
                                ScenarioStatus::Infeasible(_) => None,
                            })
                            .collect();
                        head.fold_replications(&per_rep, plan.confidence);
                    }
                }
                ScenarioOutcome { scenario, status }
            })
            .collect();
        Ok(SweepReport { outcomes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    #[test]
    fn parallel_map_preserves_order_and_first_error() {
        let items: Vec<usize> = (0..37).collect();
        let doubled = parallel_map(&items, 8, |&x| Ok(x * 2)).unwrap();
        assert_eq!(doubled, items.iter().map(|x| x * 2).collect::<Vec<_>>());

        // The error on the smallest index wins, regardless of scheduling.
        let err = parallel_map(&items, 8, |&x| {
            if x % 10 == 3 {
                Err(Error::InvalidConfig(format!("boom {x}")))
            } else {
                Ok(x)
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("boom 3"), "{err}");

        assert!(parallel_map::<usize, usize, _>(&[], 4, |&x| Ok(x)).unwrap().is_empty());
    }

    #[test]
    fn effective_threads_is_clamped_to_grid() {
        let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
            .models(vec!["tiny"])
            .partitions(vec![1, 2])
            .bandwidth_scales(vec![1.0]);
        let runner = SweepRunner::new(grid).threads(64);
        assert_eq!(runner.effective_threads(), 2);
    }

    #[test]
    fn tiny_grid_runs_and_reports() {
        let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
            .models(vec!["tiny"])
            .partitions(vec![1, 2, 4])
            .bandwidth_scales(vec![1.0])
            .steady_batches(2)
            .trace_samples(64);
        let report = SweepRunner::new(grid).threads(2).run().unwrap();
        assert_eq!(report.outcomes.len(), 3);
        assert_eq!(report.completed_count(), 3);
        assert_eq!(report.serve_count(), 0);
        // The n = 1 row is the baseline itself.
        let base = report.outcomes[0].metrics().unwrap();
        assert!((base.relative_performance - 1.0).abs() < 1e-12);
        assert_eq!(base.smoothness_cov, base.baseline_cov);
        assert_eq!(base.p99_ms, None);
    }

    #[test]
    fn mixed_tenant_rows_run_against_the_timeshared_baseline() {
        let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
            .models(vec!["tiny"])
            .partitions(vec![1])
            .bandwidth_scales(vec![1.0])
            .serve_duration(0.01)
            .steady_batches(2)
            .trace_samples(32)
            .mixed_tenants(vec!["tiny:1:2000,tiny:1:2000"]);
        let report = SweepRunner::new(grid).threads(2).run().unwrap();
        assert_eq!(report.outcomes.len(), 2);
        assert_eq!(report.completed_count(), 2);
        let mixed = report
            .outcomes
            .iter()
            .find(|o| o.scenario.tenants.is_some())
            .expect("mixed row present");
        assert_eq!(mixed.scenario.model, "mixed");
        assert!(mixed.scenario.is_serve());
        let m = mixed.metrics().unwrap();
        // Co-scheduled vs time-shared at identical offered load: the
        // relative-performance column is that comparison, and the serve
        // latency columns flow through.
        assert!(m.relative_performance > 0.0);
        assert!(m.p99_ms.is_some());
        assert!(m.goodput_ips.is_some());
        let csv = report.to_csv().to_string();
        assert!(csv.contains(",tenants,"), "tenants column in header");
        assert!(csv.contains("tiny:1:2000;tiny:1:2000"), "spec cell is ';'-joined");
        // Byte-identical across thread counts, mixed rows included.
        let again = SweepRunner::new(
            SweepGrid::new(&AcceleratorConfig::knl_7210())
                .models(vec!["tiny"])
                .partitions(vec![1])
                .bandwidth_scales(vec![1.0])
                .serve_duration(0.01)
                .steady_batches(2)
                .trace_samples(32)
                .mixed_tenants(vec!["tiny:1:2000,tiny:1:2000"]),
        )
        .threads(1)
        .run()
        .unwrap();
        assert_eq!(again.render(), report.render());
        assert_eq!(again.to_csv().to_string(), csv);
    }

    #[test]
    fn replicated_sweep_folds_ci_per_serve_row() {
        let mk = |reps: usize| {
            SweepGrid::new(&AcceleratorConfig::knl_7210())
                .models(vec!["tiny"])
                .partitions(vec![1, 2])
                .bandwidth_scales(vec![1.0])
                .arrival_rates(vec![0.0, 5000.0])
                .steady_batches(2)
                .serve_duration(0.01)
                .serve_replications(reps)
                .trace_samples(32)
        };
        let single = SweepRunner::new(mk(1)).threads(2).run().unwrap();
        let rep = SweepRunner::new(mk(3)).threads(2).run().unwrap();
        assert!(!single.is_replicated());
        assert!(rep.is_replicated());
        assert_eq!(rep.replications(), Some(3));
        for (a, b) in single.outcomes.iter().zip(&rep.outcomes) {
            // Headline (replication 0) columns match the single-run
            // sweep bit for bit; only serve rows carry statistics.
            let (ma, mb) = (a.metrics().unwrap(), b.metrics().unwrap());
            assert_eq!(ma.relative_performance.to_bits(), mb.relative_performance.to_bits());
            assert_eq!(ma.p99_ms, mb.p99_ms);
            assert_eq!(b.scenario.is_serve(), mb.replicated.is_some(), "{}", b.scenario.label());
        }
        let csv = rep.to_csv().to_string();
        assert!(csv.lines().next().unwrap().ends_with(",drop_rate_mean,drop_rate_ci95"));
        assert!(single.to_csv().to_string().lines().next().unwrap().ends_with(",reason"));
        // Byte-identical across thread counts, replications included.
        let again = SweepRunner::new(mk(3)).threads(1).run().unwrap();
        assert_eq!(again.to_csv().to_string(), csv);
        assert_eq!(again.render(), rep.render());
        assert_eq!(again.summary_json().to_string_pretty(), rep.summary_json().to_string_pretty());
    }

    #[test]
    fn mixed_offline_and_serve_grid_runs() {
        let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
            .models(vec!["tiny"])
            .partitions(vec![1, 2])
            .bandwidth_scales(vec![1.0])
            .arrival_rates(vec![0.0, 5000.0])
            .steady_batches(2)
            .serve_duration(0.01)
            .trace_samples(32);
        let report = SweepRunner::new(grid).threads(2).run().unwrap();
        assert_eq!(report.outcomes.len(), 4);
        assert_eq!(report.completed_count(), 4);
        assert_eq!(report.serve_count(), 2);
        // Offline rows have no latency columns; serve rows do.
        for o in &report.outcomes {
            let m = o.metrics().unwrap();
            assert_eq!(o.scenario.is_serve(), m.p99_ms.is_some(), "{}", o.scenario.label());
            if o.scenario.is_serve() {
                assert!(m.p99_ms.unwrap() > 0.0);
            }
        }
        // The serve n = 1 row is its own baseline.
        let serve_base = report
            .outcomes
            .iter()
            .find(|o| o.scenario.is_serve() && o.scenario.partitions == 1)
            .unwrap();
        assert!((serve_base.metrics().unwrap().relative_performance - 1.0).abs() < 1e-12);
    }
}
