//! Parallel sweep execution.
//!
//! The fluid simulator is pure and `Send`-friendly, and every sweep
//! scenario is independent, so a grid is embarrassingly parallel. The
//! runner fans scenarios out over a pool of `std::thread` workers in two
//! phases:
//!
//! 1. **baselines** — one synchronous (n = 1) run per distinct
//!    (model, bandwidth-scale) pair, shared by every partition count of
//!    that pair (the same optimization `fig5` used serially);
//! 2. **scenarios** — each grid point runs against its precomputed
//!    baseline.
//!
//! Determinism: workers pull indices from an atomic counter but write
//! results into per-index slots, and the report is assembled in index
//! order — so the aggregated output is byte-identical whether the pool
//! has 1 thread or N. Errors are deterministic too: the error attached
//! to the lowest index wins.

use super::grid::{Scenario, SweepGrid};
use super::report::{ScenarioOutcome, ScenarioStatus, SweepMetrics, SweepReport};
use crate::error::{Error, Result};
use crate::model::Graph;
use crate::shaping::{PartitionExperiment, ShapingAnalysis};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

/// Deterministic parallel map: applies `f` to every item on `threads`
/// workers and returns the results in item order. The first error in
/// item order (not completion order) is the one reported.
fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> Result<R> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.clamp(1, n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().expect("sweep slot poisoned") = Some(r);
            });
        }
    });
    let mut out = Vec::with_capacity(n);
    for slot in slots {
        match slot.into_inner().expect("sweep slot poisoned") {
            Some(Ok(r)) => out.push(r),
            Some(Err(e)) => return Err(e),
            None => {
                return Err(Error::SimInvariant(
                    "sweep worker pool dropped a scenario".into(),
                ))
            }
        }
    }
    Ok(out)
}

/// Runs a [`SweepGrid`] across a worker pool and aggregates the ranked
/// [`SweepReport`].
#[derive(Debug, Clone)]
pub struct SweepRunner {
    grid: SweepGrid,
    threads: usize,
}

impl SweepRunner {
    pub fn new(grid: SweepGrid) -> Self {
        Self { grid, threads: 0 }
    }

    /// Worker thread count; 0 (the default) uses the host's available
    /// parallelism.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// The pool size `run` will actually use.
    pub fn effective_threads(&self) -> usize {
        let hw = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let t = if self.threads == 0 { hw } else { self.threads };
        t.clamp(1, self.grid.len().max(1))
    }

    fn experiment(&self, scenario: &Scenario, graph: &Graph) -> PartitionExperiment {
        PartitionExperiment::new(&scenario.accel(&self.grid.accel), graph)
            .partitions(scenario.partitions)
            .steady_batches(scenario.steady_batches)
            .trace_samples(self.grid.trace_samples)
    }

    /// Execute the full grid and aggregate the report.
    pub fn run(&self) -> Result<SweepReport> {
        self.grid.validate()?;
        let threads = self.effective_threads();

        // Graphs are immutable once built; resolve each model once and
        // share references across the pool.
        let mut graphs: BTreeMap<String, Graph> = BTreeMap::new();
        for m in &self.grid.models {
            graphs.insert(m.clone(), crate::model::by_name(m)?);
        }

        // Phase 1: one synchronous baseline per (model, bandwidth scale).
        let mut keys: Vec<(String, f64)> = Vec::new();
        for m in &self.grid.models {
            for &s in &self.grid.bandwidth_scales {
                keys.push((m.clone(), s));
            }
        }
        let baselines_vec = parallel_map(&keys, threads, |(model, scale)| {
            let probe = Scenario {
                id: 0,
                model: model.clone(),
                partitions: 1,
                bandwidth_scale: *scale,
                steady_batches: self.grid.steady_batches,
            };
            self.experiment(&probe, &graphs[model]).run_baseline()
        })?;
        let baselines: BTreeMap<(String, u64), ShapingAnalysis> = keys
            .iter()
            .zip(baselines_vec)
            .map(|((m, s), b)| ((m.clone(), s.to_bits()), b))
            .collect();

        // Phase 2: every scenario against its shared baseline.
        let scenarios = self.grid.scenarios();
        let statuses = parallel_map(&scenarios, threads, |sc| {
            let baseline = &baselines[&(sc.model.clone(), sc.bandwidth_scale.to_bits())];
            if sc.partitions == 1 {
                return Ok(ScenarioStatus::Completed(SweepMetrics::baseline_row(baseline)));
            }
            match self.experiment(sc, &graphs[&sc.model]).run_against(baseline) {
                Ok(report) => Ok(ScenarioStatus::Completed(SweepMetrics::from_report(&report))),
                Err(Error::InfeasiblePartitioning(why)) => Ok(ScenarioStatus::Infeasible(why)),
                Err(e) => Err(e),
            }
        })?;

        let outcomes = scenarios
            .into_iter()
            .zip(statuses)
            .map(|(scenario, status)| ScenarioOutcome { scenario, status })
            .collect();
        Ok(SweepReport { outcomes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    #[test]
    fn parallel_map_preserves_order_and_first_error() {
        let items: Vec<usize> = (0..37).collect();
        let doubled = parallel_map(&items, 8, |&x| Ok(x * 2)).unwrap();
        assert_eq!(doubled, items.iter().map(|x| x * 2).collect::<Vec<_>>());

        // The error on the smallest index wins, regardless of scheduling.
        let err = parallel_map(&items, 8, |&x| {
            if x % 10 == 3 {
                Err(Error::InvalidConfig(format!("boom {x}")))
            } else {
                Ok(x)
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("boom 3"), "{err}");

        assert!(parallel_map::<usize, usize, _>(&[], 4, |&x| Ok(x)).unwrap().is_empty());
    }

    #[test]
    fn effective_threads_is_clamped_to_grid() {
        let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
            .models(vec!["tiny"])
            .partitions(vec![1, 2])
            .bandwidth_scales(vec![1.0]);
        let runner = SweepRunner::new(grid).threads(64);
        assert_eq!(runner.effective_threads(), 2);
    }

    #[test]
    fn tiny_grid_runs_and_reports() {
        let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
            .models(vec!["tiny"])
            .partitions(vec![1, 2, 4])
            .bandwidth_scales(vec![1.0])
            .steady_batches(2)
            .trace_samples(64);
        let report = SweepRunner::new(grid).threads(2).run().unwrap();
        assert_eq!(report.outcomes.len(), 3);
        assert_eq!(report.completed_count(), 3);
        // The n = 1 row is the baseline itself.
        let base = report.outcomes[0].metrics().unwrap();
        assert!((base.relative_performance - 1.0).abs() < 1e-12);
        assert_eq!(base.smoothness_cov, base.baseline_cov);
    }
}
