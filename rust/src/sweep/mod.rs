//! Parallel scenario-sweep engine.
//!
//! The paper's evaluation — and every ROADMAP direction built on it — is
//! a design-space sweep: networks × partition counts × bandwidth
//! configurations. This module turns that into a first-class subsystem:
//!
//! * [`SweepGrid`] enumerates the cartesian product of scenarios —
//!   models × bandwidth scales × stagger policies × arrival rates ×
//!   partition counts, where a positive arrival rate turns the point
//!   into a serving run (see [`crate::serve`]);
//! * [`SweepRunner`] fans them out across `std::thread` workers (the
//!   fluid simulator is pure, so scenarios are embarrassingly parallel)
//!   with per-(model, bandwidth, rate) baselines computed once and
//!   shared;
//! * [`SweepReport`] aggregates the outcomes into a ranked table with
//!   relative-performance, traffic-smoothness (coefficient of
//!   variation) and p50/p95/p99 latency columns, plus CSV/JSON exports;
//! * [`ReplicationPlan`] (see [`replicate`]) repeats serve scenarios
//!   under SplitMix64-derived seeds and reduces the tail metrics to
//!   mean ± 95 % t-intervals, so ranked comparisons carry error bars
//!   instead of single-seed point estimates.
//!
//! Results are byte-identical for 1 vs N worker threads: outcomes are
//! keyed by scenario id (and replication index) and reassembled in grid
//! order — the determinism contract `docs/ARCHITECTURE.md` spells out.
//!
//! ```no_run
//! use trafficshape::config::AcceleratorConfig;
//! use trafficshape::sweep::{SweepGrid, SweepRunner};
//!
//! let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
//!     .models(vec!["resnet50", "googlenet"])
//!     .partitions(vec![1, 2, 4, 8, 16])
//!     .bandwidth_scales(vec![1.0, 0.75]);
//! let report = SweepRunner::new(grid).run().unwrap();
//! print!("{}", report.render());
//! ```

mod grid;
pub mod replicate;
mod report;
mod runner;

pub use grid::{Scenario, SweepGrid, DEFAULT_SWEEP_MODELS};
pub use replicate::{MetricCi, ProfileBin, ReplicatedMetrics, ReplicationPlan, ReplicationProfile};
pub use report::{ScenarioOutcome, ScenarioStatus, SweepMetrics, SweepReport};
pub(crate) use runner::parallel_map;
pub use runner::SweepRunner;
