//! Parallel scenario-sweep engine.
//!
//! The paper's evaluation — and every ROADMAP direction built on it — is
//! a design-space sweep: networks × partition counts × bandwidth
//! configurations. This module turns that into a first-class subsystem:
//!
//! * [`SweepGrid`] enumerates the cartesian product of scenarios;
//! * [`SweepRunner`] fans them out across `std::thread` workers (the
//!   fluid simulator is pure, so scenarios are embarrassingly parallel)
//!   with per-(model, bandwidth) baselines computed once and shared;
//! * [`SweepReport`] aggregates the outcomes into a ranked table with
//!   relative-performance and traffic-smoothness (coefficient of
//!   variation) columns, plus CSV/JSON exports.
//!
//! Results are byte-identical for 1 vs N worker threads: outcomes are
//! keyed by scenario id and reassembled in grid order.
//!
//! ```no_run
//! use trafficshape::config::AcceleratorConfig;
//! use trafficshape::sweep::{SweepGrid, SweepRunner};
//!
//! let grid = SweepGrid::new(&AcceleratorConfig::knl_7210())
//!     .models(vec!["resnet50", "googlenet"])
//!     .partitions(vec![1, 2, 4, 8, 16])
//!     .bandwidth_scales(vec![1.0, 0.75]);
//! let report = SweepRunner::new(grid).run().unwrap();
//! print!("{}", report.render());
//! ```

mod grid;
mod report;
mod runner;

pub use grid::{Scenario, SweepGrid, DEFAULT_SWEEP_MODELS};
pub use report::{ScenarioOutcome, ScenarioStatus, SweepMetrics, SweepReport};
pub use runner::SweepRunner;
