//! Monte-Carlo replication: the same scenario run under many derived
//! seeds, reduced to mean ± 95 % confidence intervals.
//!
//! Every tail metric the single-run reports quote — p99, drop rate,
//! goodput at overload — is a point estimate of a random quantity: the
//! arrival stream is stochastic, and the paper's argument is itself
//! statistical (asynchronous partitions de-correlate traffic so the
//! aggregate σ shrinks as root-sum-square). A [`ReplicationPlan`] makes
//! those estimates defensible: it derives one seed per replication from
//! the scenario's base seed via a SplitMix64 sub-stream, the front-ends
//! fan the replications out over the existing `parallel_map` pool, and
//! [`ReplicatedMetrics`] folds the per-replication outcomes into mean,
//! sample standard deviation and a two-sided Student-t interval per
//! metric (95 % by default; `--confidence {90,95,99}` retunes both the
//! critical values and the `*_ci<pct>` artifact column names).
//!
//! Two contracts the harness guarantees:
//!
//! * **Replication 0 is the base seed.** `seeds()[0] == base_seed`, so a
//!   `--replications 1` run *is* today's single-run path and reproduces
//!   its reports byte for byte.
//! * **Thread-count independence.** Aggregation is an id-keyed fold over
//!   the replication index — the same deterministic reduction whatever
//!   order the worker threads finish in — so every mean/CI column and
//!   [`ReplicationProfile`] bin is byte-identical across `--threads 1/N`.

use crate::error::{Error, Result};
use crate::serve::ServeOutcome;
use crate::util::csv::CsvWriter;
use crate::util::rng::SplitMix64;
use crate::util::stats::{t_critical, Confidence};

/// How many times to repeat a scenario and under which seed lineage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicationPlan {
    /// Number of independent runs (≥ 1; 1 = the classic single run).
    pub replications: usize,
    /// The scenario seed replication seeds are derived from.
    pub base_seed: u64,
    /// Interval coverage for every folded metric (default 95 %, which
    /// keeps the historical `*_ci95` artifact columns byte-identical).
    pub confidence: Confidence,
}

impl ReplicationPlan {
    pub fn new(replications: usize, base_seed: u64) -> Self {
        Self { replications, base_seed, confidence: Confidence::default() }
    }

    /// Builder-style override of the interval coverage.
    pub fn confidence(mut self, confidence: Confidence) -> Self {
        self.confidence = confidence;
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.replications == 0 {
            return Err(Error::InvalidConfig("replications must be >= 1".into()));
        }
        Ok(())
    }

    /// Whether more than one replication runs (i.e. CI columns appear).
    pub fn is_replicated(&self) -> bool {
        self.replications > 1
    }

    /// The per-replication seeds. Replication 0 keeps the base seed
    /// itself (see the module contract); replications 1.. draw from a
    /// SplitMix64 sub-stream of the base seed, so any two plans sharing
    /// a base seed agree on every prefix.
    pub fn seeds(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.replications);
        out.push(self.base_seed);
        let mut stream = SplitMix64::new(self.base_seed);
        while out.len() < self.replications {
            out.push(stream.next_u64());
        }
        out
    }
}

/// Mean ± dispersion of one metric over the replications.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricCi {
    /// Sample size (the number of replications folded in).
    pub n: usize,
    pub mean: f64,
    /// Sample (n − 1) standard deviation — an *estimate* of the run-to-
    /// run σ, unlike [`crate::util::stats::Summary::std`]'s population
    /// convention for full traces.
    pub std: f64,
    /// Half-width of the two-sided Student-t interval at
    /// [`Self::confidence`], `t_{q, n−1} · s / √n` (0 when n < 2).
    pub ci: f64,
    /// The coverage [`Self::ci`] was computed at.
    pub confidence: Confidence,
}

impl MetricCi {
    /// Fold at the default 95 % coverage.
    pub fn of(xs: &[f64]) -> Self {
        Self::of_at(xs, Confidence::default())
    }

    /// Fold at an explicit coverage level.
    pub fn of_at(xs: &[f64], confidence: Confidence) -> Self {
        let n = xs.len();
        if n == 0 {
            return Self { n: 0, mean: 0.0, std: 0.0, ci: 0.0, confidence };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        if n == 1 {
            return Self { n, mean, std: 0.0, ci: 0.0, confidence };
        }
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
        let std = var.sqrt();
        let ci = t_critical(confidence, n - 1) * std / (n as f64).sqrt();
        Self { n, mean, std, ci, confidence }
    }

    /// Conservative lower edge of the interval, `mean − ci`. Rankings
    /// sort on this so a scenario only outranks another when its whole
    /// interval supports the claim; with a single replication `ci` is 0
    /// and this degrades to the point estimate (byte-identical ranks).
    pub fn lower_bound(&self) -> f64 {
        self.mean - self.ci
    }

    /// The `mean±ci` cell used by the render tables.
    pub fn render(&self, decimals: usize) -> String {
        format!("{:.*}±{:.*}", decimals, self.mean, decimals, self.ci)
    }
}

/// The six headline metrics as replication statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicatedMetrics {
    pub p50_ms: MetricCi,
    pub p95_ms: MetricCi,
    pub p99_ms: MetricCi,
    pub throughput_ips: MetricCi,
    pub goodput_ips: MetricCi,
    pub drop_rate: MetricCi,
}

impl ReplicatedMetrics {
    /// The CSV columns every replicated report appends, in cell order.
    pub const CSV_COLUMNS: [&'static str; 12] = [
        "p50_ms_mean",
        "p50_ms_ci95",
        "p95_ms_mean",
        "p95_ms_ci95",
        "p99_ms_mean",
        "p99_ms_ci95",
        "throughput_ips_mean",
        "throughput_ips_ci95",
        "goodput_ips_mean",
        "goodput_ips_ci95",
        "drop_rate_mean",
        "drop_rate_ci95",
    ];

    /// The six folded metric names, in cell order.
    const METRICS: [&'static str; 6] =
        ["p50_ms", "p95_ms", "p99_ms", "throughput_ips", "goodput_ips", "drop_rate"];

    /// The CSV columns for a report folded at `confidence` — identical
    /// to [`Self::CSV_COLUMNS`] at the default 95 % level.
    pub fn csv_columns_at(confidence: Confidence) -> Vec<String> {
        Self::METRICS
            .iter()
            .flat_map(|m| [format!("{m}_mean"), format!("{m}_{}", confidence.suffix())])
            .collect()
    }

    /// Fold rows of `[p50_ms, p95_ms, p99_ms, throughput, goodput,
    /// drop_rate]` samples, one row per replication, at 95 % coverage.
    pub fn from_rows(rows: &[[f64; 6]]) -> Self {
        Self::from_rows_at(rows, Confidence::default())
    }

    /// [`Self::from_rows`] at an explicit coverage level.
    pub fn from_rows_at(rows: &[[f64; 6]], confidence: Confidence) -> Self {
        let col = |i: usize| {
            MetricCi::of_at(&rows.iter().map(|r| r[i]).collect::<Vec<f64>>(), confidence)
        };
        Self {
            p50_ms: col(0),
            p95_ms: col(1),
            p99_ms: col(2),
            throughput_ips: col(3),
            goodput_ips: col(4),
            drop_rate: col(5),
        }
    }

    /// Fold per-replication serve outcomes (replication-index order) at
    /// an explicit coverage level.
    pub fn from_outcomes_at(outcomes: &[&ServeOutcome], confidence: Confidence) -> Self {
        let rows: Vec<[f64; 6]> = outcomes
            .iter()
            .map(|o| {
                [
                    o.latency.p50_ms,
                    o.latency.p95_ms,
                    o.latency.p99_ms,
                    o.throughput_ips,
                    o.goodput_ips,
                    o.drop_rate,
                ]
            })
            .collect();
        Self::from_rows_at(&rows, confidence)
    }

    /// Fold per-replication serve outcomes (replication-index order).
    pub fn from_outcomes(outcomes: &[&ServeOutcome]) -> Self {
        Self::from_outcomes_at(outcomes, Confidence::default())
    }

    /// Number of replications folded in.
    pub fn replications(&self) -> usize {
        self.p99_ms.n
    }

    /// The coverage level this fold was computed at.
    pub fn confidence(&self) -> Confidence {
        self.p99_ms.confidence
    }

    /// CSV cells matching [`Self::csv_columns_at`] (and, at the default
    /// level, [`Self::CSV_COLUMNS`]).
    pub fn csv_cells(&self) -> Vec<String> {
        let f = crate::util::csv::format_float;
        [
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.throughput_ips,
            self.goodput_ips,
            self.drop_rate,
        ]
        .iter()
        .flat_map(|m| [f(m.mean), f(m.ci)])
        .collect()
    }
}

/// One time bin of a [`ReplicationProfile`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfileBin {
    pub t_start_s: f64,
    pub t_end_s: f64,
    /// Requests arriving inside the bin (mean ± CI over replications).
    pub arrived: MetricCi,
    /// Requests completing service inside the bin.
    pub served: MetricCi,
    /// Backlog at the bin's end: cumulative arrived − cumulative served
    /// (dropped requests stay counted in — they occupied a queue slot
    /// until shed, and the shed instant is not recorded).
    pub backlog: MetricCi,
}

/// Arrived / served / backlog per fixed-width time bin, mean ± CI across
/// replications — the plottable profile of a replicated serving run (the
/// rs-sim-style per-timestep aggregate, with error bars).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicationProfile {
    pub bins: Vec<ProfileBin>,
}

impl ReplicationProfile {
    /// Bin count the serve front-end exports.
    pub const DEFAULT_BINS: usize = 50;

    /// Bin every replication's request timeline over the common span
    /// `[0, max event instant)` and fold the per-bin counts across
    /// replications. Returns an empty profile when no replication saw
    /// any event.
    pub fn from_outcomes(outcomes: &[&ServeOutcome], bins: usize) -> Self {
        Self::from_outcomes_at(outcomes, bins, Confidence::default())
    }

    /// [`Self::from_outcomes`] at an explicit coverage level.
    pub fn from_outcomes_at(outcomes: &[&ServeOutcome], bins: usize, conf: Confidence) -> Self {
        assert!(bins > 0, "profile needs at least one bin");
        let span = outcomes
            .iter()
            .flat_map(|o| o.arrival_times_s.iter().chain(o.finish_times_s.iter()))
            .fold(0.0f64, |a, &t| a.max(t));
        if !(span > 0.0) {
            return Self::default();
        }
        let width = span / bins as f64;
        // Per replication: arrived / served counts per bin, then the
        // running backlog at each bin edge.
        let mut arrived = vec![Vec::with_capacity(outcomes.len()); bins];
        let mut served = vec![Vec::with_capacity(outcomes.len()); bins];
        let mut backlog = vec![Vec::with_capacity(outcomes.len()); bins];
        for o in outcomes {
            let count = |ts: &[f64]| {
                let mut c = vec![0usize; bins];
                for &t in ts {
                    let b = ((t / width) as usize).min(bins - 1);
                    c[b] += 1;
                }
                c
            };
            let a = count(&o.arrival_times_s);
            let s = count(&o.finish_times_s);
            let mut backlogged = 0i64;
            for b in 0..bins {
                arrived[b].push(a[b] as f64);
                served[b].push(s[b] as f64);
                backlogged += a[b] as i64 - s[b] as i64;
                backlog[b].push(backlogged as f64);
            }
        }
        let bins_out = (0..bins)
            .map(|b| ProfileBin {
                t_start_s: b as f64 * width,
                t_end_s: (b + 1) as f64 * width,
                arrived: MetricCi::of_at(&arrived[b], conf),
                served: MetricCi::of_at(&served[b], conf),
                backlog: MetricCi::of_at(&backlog[b], conf),
            })
            .collect();
        Self { bins: bins_out }
    }

    pub fn is_empty(&self) -> bool {
        self.bins.is_empty()
    }

    /// The coverage the bins were folded at (default for an empty
    /// profile).
    pub fn confidence(&self) -> Confidence {
        self.bins.first().map_or_else(Confidence::default, |b| b.arrived.confidence)
    }

    /// Header of [`Self::to_csv`] at the default 95 % coverage.
    pub fn csv_columns() -> Vec<&'static str> {
        vec![
            "bin",
            "t_start_s",
            "t_end_s",
            "arrived_mean",
            "arrived_ci95",
            "served_mean",
            "served_ci95",
            "backlog_mean",
            "backlog_ci95",
        ]
    }

    /// Header of [`Self::to_csv`] at `conf` — [`Self::csv_columns`]
    /// with the interval suffix renamed.
    pub fn csv_columns_at(conf: Confidence) -> Vec<String> {
        let sfx = conf.suffix();
        let mut cols = vec!["bin".to_string(), "t_start_s".into(), "t_end_s".into()];
        for m in ["arrived", "served", "backlog"] {
            cols.push(format!("{m}_mean"));
            cols.push(format!("{m}_{sfx}"));
        }
        cols
    }

    /// One row per time bin.
    pub fn to_csv(&self) -> CsvWriter {
        let mut w = CsvWriter::new(Self::csv_columns_at(self.confidence()));
        let f = crate::util::csv::format_float;
        for (i, b) in self.bins.iter().enumerate() {
            w.row(vec![
                i.to_string(),
                f(b.t_start_s),
                f(b.t_end_s),
                f(b.arrived.mean),
                f(b.arrived.ci),
                f(b.served.mean),
                f(b.served.ci),
                f(b.backlog.mean),
                f(b.backlog.ci),
            ]);
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_seeds_start_at_the_base_seed_and_agree_on_prefixes() {
        let p = ReplicationPlan::new(4, 42);
        p.validate().unwrap();
        let seeds = p.seeds();
        assert_eq!(seeds.len(), 4);
        assert_eq!(seeds[0], 42, "replication 0 must be the base seed");
        // Derived seeds are distinct from each other and the base.
        for i in 0..seeds.len() {
            for j in 0..i {
                assert_ne!(seeds[i], seeds[j], "seed collision at ({i}, {j})");
            }
        }
        // Prefix-stable: a bigger plan with the same base agrees.
        assert_eq!(ReplicationPlan::new(2, 42).seeds(), seeds[..2]);
        // A single-replication plan is exactly the base seed.
        assert_eq!(ReplicationPlan::new(1, 7).seeds(), vec![7]);
        assert!(!ReplicationPlan::new(1, 7).is_replicated());
        assert!(ReplicationPlan::new(2, 7).is_replicated());
        assert!(ReplicationPlan::new(0, 7).validate().is_err());
        // Different base seeds diverge immediately after index 0.
        assert_ne!(ReplicationPlan::new(3, 1).seeds()[1], ReplicationPlan::new(3, 2).seeds()[1]);
    }

    #[test]
    fn metric_ci_matches_the_closed_form() {
        // n = 1: no dispersion information, interval collapses.
        let one = MetricCi::of(&[5.0]);
        assert_eq!((one.n, one.mean, one.std, one.ci), (1, 5.0, 0.0, 0.0));
        assert_eq!(MetricCi::of(&[]).n, 0);
        // n = 4 sample: mean 5, sample std sqrt(20/3).
        let m = MetricCi::of(&[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(m.n, 4);
        assert!((m.mean - 5.0).abs() < 1e-12);
        let s = (20.0f64 / 3.0).sqrt();
        assert!((m.std - s).abs() < 1e-12);
        assert!((m.ci - 3.182 * s / 2.0).abs() < 1e-9, "t(3) = 3.182");
        // Zero-variance replications give a zero-width interval.
        let flat = MetricCi::of(&[3.0, 3.0, 3.0]);
        assert_eq!(flat.std, 0.0);
        assert_eq!(flat.ci, 0.0);
        assert_eq!(flat.render(2), "3.00±0.00");
    }

    #[test]
    fn replicated_metrics_fold_per_column() {
        let rows = [[1.0, 2.0, 3.0, 100.0, 90.0, 0.1], [3.0, 4.0, 5.0, 120.0, 110.0, 0.3]];
        let m = ReplicatedMetrics::from_rows(&rows);
        assert_eq!(m.replications(), 2);
        assert!((m.p50_ms.mean - 2.0).abs() < 1e-12);
        assert!((m.p99_ms.mean - 4.0).abs() < 1e-12);
        assert!((m.throughput_ips.mean - 110.0).abs() < 1e-12);
        assert!((m.drop_rate.mean - 0.2).abs() < 1e-12);
        assert!(m.p99_ms.ci > 0.0, "two distinct samples → nonzero CI");
        let cells = m.csv_cells();
        assert_eq!(cells.len(), ReplicatedMetrics::CSV_COLUMNS.len());
        assert_eq!(cells[4], "4", "p99 mean cell");
    }

    #[test]
    fn confidence_threads_into_folds_and_column_names() {
        use crate::util::stats::t_critical;
        use Confidence::{P90, P95, P99};
        let xs = [2.0, 4.0, 6.0, 8.0];
        let m95 = MetricCi::of(&xs);
        assert_eq!(m95.confidence, P95, "default coverage is 95 %");
        for conf in [P90, P95, P99] {
            let m = MetricCi::of_at(&xs, conf);
            assert_eq!(m.confidence, conf);
            assert_eq!((m.n, m.mean, m.std), (m95.n, m95.mean, m95.std));
            assert!((m.ci - t_critical(conf, 3) * m.std / 2.0).abs() < 1e-12);
        }
        // Wider coverage, wider interval.
        assert!(MetricCi::of_at(&xs, P90).ci < MetricCi::of_at(&xs, P99).ci);
        // Default column names are the historical ci95 set; other
        // levels only rename the suffix.
        let c95: Vec<String> =
            ReplicatedMetrics::CSV_COLUMNS.iter().map(|s| s.to_string()).collect();
        assert_eq!(ReplicatedMetrics::csv_columns_at(P95), c95);
        let c99 = ReplicatedMetrics::csv_columns_at(P99);
        assert_eq!(c99[5], "p99_ms_ci99");
        assert_eq!(c99[4], "p99_ms_mean");
        // The replication plan carries its coverage into the fold.
        let plan = ReplicationPlan::new(3, 42).confidence(P99);
        assert_eq!(plan.confidence, P99);
        assert_eq!(plan.seeds(), ReplicationPlan::new(3, 42).seeds(), "seeds ignore coverage");
        let folded = ReplicatedMetrics::from_rows_at(
            &[[1.0, 2.0, 3.0, 4.0, 5.0, 0.1], [2.0, 3.0, 4.0, 5.0, 6.0, 0.2]],
            plan.confidence,
        );
        assert_eq!(folded.confidence(), P99);
        // Profiles carry the coverage into their header.
        let mut o = ServeOutcome::empty(1, 0.0);
        o.arrival_times_s = vec![0.1, 0.6];
        o.finish_times_s = vec![0.4, 1.0];
        let p = ReplicationProfile::from_outcomes_at(&[&o], 2, P90);
        assert_eq!(p.confidence(), P90);
        let header = p.to_csv().to_string().lines().next().map(str::to_string);
        let want = "bin,t_start_s,t_end_s,arrived_mean,arrived_ci90,served_mean,\
                    served_ci90,backlog_mean,backlog_ci90";
        assert_eq!(header.as_deref(), Some(want));
    }

    #[test]
    fn profile_bins_count_arrivals_served_and_backlog() {
        // Hand-built outcomes: only the timeline fields matter here.
        let mk = |arrivals: Vec<f64>, finishes: Vec<f64>| {
            let mut o = ServeOutcome::empty(1, 0.0);
            o.arrival_times_s = arrivals;
            o.finish_times_s = finishes;
            o
        };
        let a = mk(vec![0.1, 0.3, 0.6], vec![0.4, 0.7, 0.9]);
        let b = mk(vec![0.1, 0.2, 0.6], vec![0.5, 0.8, 1.0]);
        let p = ReplicationProfile::from_outcomes(&[&a, &b], 2);
        assert_eq!(p.bins.len(), 2);
        // Span is 1.0 (the latest finish), so bins are [0, 0.5) / [0.5, 1.0].
        assert!((p.bins[0].t_end_s - 0.5).abs() < 1e-12);
        assert!((p.bins[1].t_end_s - 1.0).abs() < 1e-12);
        // Rep a: bin 0 arrived 2, served 1; rep b: arrived 2, served 0.
        assert!((p.bins[0].arrived.mean - 2.0).abs() < 1e-12);
        assert!((p.bins[0].served.mean - 0.5).abs() < 1e-12);
        // Backlogs at the first edge: a = 1, b = 2 → mean 1.5.
        assert!((p.bins[0].backlog.mean - 1.5).abs() < 1e-12);
        assert!(p.bins[0].backlog.ci > 0.0);
        // Everything drains by the end in both replications.
        assert!((p.bins[1].backlog.mean - 0.0).abs() < 1e-12);
        let csv = p.to_csv().to_string();
        assert!(csv.starts_with("bin,t_start_s,t_end_s,arrived_mean"));
        assert_eq!(csv.lines().count(), 3);
        // No events at all → empty profile, empty-but-headed CSV.
        let empty = ReplicationProfile::from_outcomes(&[&mk(vec![], vec![])], 4);
        assert!(empty.is_empty());
        assert_eq!(empty.to_csv().to_string().lines().count(), 1);
    }
}
