//! One machine's engine window: the unit of parallel work in a cluster
//! run.
//!
//! A machine hosts one or more *lanes* — request streams bound to a
//! model and a core slice. In routed mode every machine has exactly one
//! lane (its share of the front-door stream over the cluster-wide
//! model); in placed mode each hosted tenant is a lane. Between failure
//! boundaries a machine's lanes are fixed, so each window is a
//! self-contained [`SimEngine::run_dynamic`] run that can fan out over
//! the sweep thread pool; all mutation of cluster state happens in the
//! sequential fold between windows, keyed by machine index so results
//! are byte-identical across thread counts.

use std::ops::Range;
use std::sync::Arc;

use crate::config::AcceleratorConfig;
use crate::error::{Error, Result};
use crate::model::Graph;
use crate::serve::{
    stagger_gates, BatchPolicy, DispatchPolicy, EpochWindow, LatencyRecorder, PartitionSet,
    QueueConfig, ServeController,
};
use crate::shaping::StaggerPolicy;
use crate::util::units::Seconds;
use crate::sim::{BandwidthTrace, DynJob, DynNext, SimEngine, WorkSource};

/// One request stream bound to a model and (currently) a machine. The
/// admit/born streams live in [`super::ClusterSimulator::run`], parallel
/// to this state, so windows can borrow them immutably while the fold
/// mutates the lane.
#[derive(Debug)]
pub(crate) struct Lane {
    pub graph: Graph,
    /// Asynchronous partitions within the lane's core slice.
    pub partitions: usize,
    pub queue_cap: usize,
    pub slo_ms: f64,
    /// Relative core-share weight among the lanes of one machine
    /// (placed mode; routed lanes own their whole machine).
    pub share: f64,
    /// Machine currently hosting the lane.
    pub machine: usize,
    /// Machine the lane was placed on at t=0 (fail-back target).
    pub home: usize,
    /// Admitted-stream index of the first request not yet offered to a
    /// window.
    pub cursor: usize,
    pub carry: Vec<usize>,
    pub gap_carry: Vec<f64>,
    pub last_dispatch: Option<f64>,
    /// Live absolute gates; empty means "re-stagger at the next window
    /// start" (set after placement moves and restarts).
    pub gates: Vec<f64>,
    /// Requests spliced into the admit stream since the last window
    /// fold; they were already counted as `re_routed_in`, so the fold
    /// subtracts them from the hosting machine's `routed`.
    pub spliced_pending: usize,
    pub served: usize,
    pub dropped: usize,
}

impl Lane {
    pub(crate) fn new(graph: Graph, machine: usize) -> Self {
        Self {
            graph,
            partitions: 1,
            queue_cap: 0,
            slo_ms: 0.0,
            share: 1.0,
            machine,
            home: machine,
            cursor: 0,
            carry: Vec::new(),
            gap_carry: Vec::new(),
            last_dispatch: None,
            gates: Vec::new(),
            spliced_pending: 0,
            served: 0,
            dropped: 0,
        }
    }
}

/// Per-machine accumulators folded across windows.
#[derive(Debug)]
pub(crate) struct MachineState {
    /// Front-door arrivals assigned to this machine (routed mode) or
    /// admitted by its hosted lanes (placed mode).
    pub routed: usize,
    /// Requests inherited from another machine's failure.
    pub re_routed_in: usize,
    /// Requests handed off when this machine failed.
    pub re_routed_out: usize,
    pub served: usize,
    pub dropped: usize,
    pub batches: usize,
    pub queue_peak: usize,
    pub total_bytes: f64,
    /// Weight-transfer bytes charged for tenant migrations onto this
    /// machine.
    pub migrated_bytes: f64,
    pub trace: BandwidthTrace,
    /// Sojourn times measured from *birth* (front-door arrival), so
    /// re-route delay counts against the SLO. The recorder itself is
    /// SLO-less; hits are tallied manually per lane deadline.
    pub recorder: LatencyRecorder,
    pub slo_hits: usize,
    pub failed: bool,
    pub restarted: bool,
}

impl MachineState {
    pub(crate) fn new() -> Self {
        Self {
            routed: 0,
            re_routed_in: 0,
            re_routed_out: 0,
            served: 0,
            dropped: 0,
            batches: 0,
            queue_peak: 0,
            total_bytes: 0.0,
            migrated_bytes: 0.0,
            trace: BandwidthTrace::total_only(),
            recorder: LatencyRecorder::new(),
            slo_hits: 0,
            failed: false,
            restarted: false,
        }
    }
}

/// One lane's slice of a window job: everything `run_machine_window`
/// needs, with the admit stream borrowed from the cluster run.
#[derive(Debug)]
pub(crate) struct LaneJob<'a> {
    /// Global lane index (for the fold).
    pub lane: usize,
    /// The lane's installed topology, built (and cached) by the cluster
    /// loop — windows share one compiled slice until hosting changes.
    pub set: Arc<PartitionSet>,
    pub queue_cap: usize,
    pub slo_ms: f64,
    /// The lane's full admitted arrival stream (absolute seconds).
    pub admit: &'a [f64],
    /// Stream indices offered to this window.
    pub range: Range<usize>,
    pub carry: Vec<usize>,
    pub gap_carry: Vec<f64>,
    pub last_dispatch: Option<f64>,
    /// Absolute gates; empty re-staggers at `start`.
    pub gates: Vec<f64>,
}

/// One machine's work for one inter-boundary window.
#[derive(Debug)]
pub(crate) struct WindowJob<'a> {
    pub machine: usize,
    pub accel: AcceleratorConfig,
    pub policy: DispatchPolicy,
    pub stagger: StaggerPolicy,
    pub batch_timeout_ms: f64,
    pub stagger_rearm: bool,
    pub rearm_quantile: f64,
    pub start: f64,
    /// `None` = run to drain (the final window).
    pub horizon: Option<f64>,
    pub lanes: Vec<LaneJob<'a>>,
}

/// What one lane carries out of a window.
#[derive(Debug)]
pub(crate) struct LaneFold {
    pub lane: usize,
    pub stream_arrived: usize,
    pub carried_in: usize,
    pub served: usize,
    pub dropped: usize,
    pub batches: usize,
    pub queue_peak: usize,
    pub carry: Vec<usize>,
    pub gap_carry: Vec<f64>,
    pub last_dispatch: Option<f64>,
    pub gates: Vec<f64>,
    /// `(admit index, finish time)` per completed request, in engine
    /// completion order.
    pub completions: Vec<(usize, f64)>,
}

/// What one machine carries out of a window.
#[derive(Debug)]
pub(crate) struct MachineFold {
    pub machine: usize,
    pub makespan: f64,
    pub trace: BandwidthTrace,
    pub total_bytes: f64,
    pub lanes: Vec<LaneFold>,
}

/// [`MtController`]'s shape one level up: multiplex several per-lane
/// [`ServeController`]s behind one engine, re-tagging job ids globally.
struct LaneMux<'a> {
    subs: Vec<ServeController<'a>>,
    /// Global partition -> (lane slot, the lane's local partition).
    map: Vec<(usize, usize)>,
    /// Global job id -> (lane slot, the lane's local batch id).
    batch_map: Vec<(usize, u64)>,
}

impl WorkSource for LaneMux<'_> {
    fn next(&mut self, partition: usize, now: f64) -> DynNext {
        let (s, local) = self.map[partition];
        match self.subs[s].next(local, now) {
            DynNext::Job(job) => {
                let gid = self.batch_map.len() as u64;
                self.batch_map.push((s, job.id));
                DynNext::Job(DynJob { id: gid, phases: job.phases })
            }
            other => other,
        }
    }
}

/// Run one machine's window to its horizon (or to drain) and fold the
/// engine results back per lane. Pure with respect to cluster state:
/// everything mutable is owned by the job or returned in the fold.
pub(crate) fn run_machine_window(job: &WindowJob<'_>) -> Result<MachineFold> {
    let mut subs: Vec<ServeController<'_>> = Vec::with_capacity(job.lanes.len());
    let mut map: Vec<(usize, usize)> = Vec::new();
    let mut all_cores: Vec<usize> = Vec::new();
    for (slot, lane) in job.lanes.iter().enumerate() {
        let set = &lane.set;
        let gates: Vec<f64> = if lane.gates.is_empty() {
            stagger_gates(job.stagger, set.partitions, set.batch_time_s)
                .into_iter()
                .map(|o| job.start + o)
                .collect()
        } else {
            lane.gates.clone()
        };
        let n = gates.len();
        let mut cfg = QueueConfig::new(job.policy, gates);
        cfg.queue_cap = (lane.queue_cap > 0).then_some(lane.queue_cap);
        cfg.slo_s = (lane.slo_ms > 0.0).then_some(Seconds::from_ms(lane.slo_ms).value());
        cfg.batch = BatchPolicy::from_timeout_ms(job.batch_timeout_ms)?;
        cfg.rearm_idle_s = job.stagger_rearm.then_some(set.batch_time_s);
        cfg.rearm_quantile = (job.rearm_quantile > 0.0).then_some(job.rearm_quantile);
        // Gates are absolute, so lull re-arms need the relative offsets.
        cfg.rearm_offsets = Some(stagger_gates(job.stagger, n, set.batch_time_s));
        let window = EpochWindow {
            start_s: job.start,
            horizon_s: job.horizon,
            stream: lane.range.clone(),
            carry: lane.carry.clone(),
            gap_carry: lane.gap_carry.clone(),
            last_dispatch: lane.last_dispatch,
        };
        subs.push(ServeController::for_epoch(lane.admit, set.programs(), cfg, window));
        for p in 0..set.partitions {
            map.push((slot, p));
            all_cores.push(set.cores_per_partition);
        }
    }

    let engine = SimEngine::new(&job.accel);
    let mut mux = LaneMux { subs, map, batch_map: Vec::new() };
    let out = engine.run_dynamic(&all_cores, &mut mux)?;

    let mut served = vec![0usize; job.lanes.len()];
    let mut completions: Vec<Vec<(usize, f64)>> = vec![Vec::new(); job.lanes.len()];
    for engine_job in &out.jobs {
        let Some(&(slot, local)) = mux.batch_map.get(engine_job.id as usize) else {
            return Err(Error::SimInvariant(format!(
                "engine job {} has no dispatched lane batch",
                engine_job.id
            )));
        };
        let batch = &mux.subs[slot].batches()[local as usize];
        for &r in &batch.requests {
            completions[slot].push((r, engine_job.finished_at));
        }
        served[slot] += batch.requests.len();
    }

    let mut lanes = Vec::with_capacity(job.lanes.len());
    for (slot, lane) in job.lanes.iter().enumerate() {
        let sub = &mut mux.subs[slot];
        let dropped = sub.dropped();
        let carry = sub.drain_remaining();
        let (gap_carry, last_dispatch) = sub.gap_state();
        let fold = LaneFold {
            lane: lane.lane,
            stream_arrived: lane.range.len(),
            carried_in: lane.carry.len(),
            served: served[slot],
            dropped,
            batches: sub.batches().len(),
            queue_peak: sub.queue_peak(),
            carry,
            gap_carry,
            last_dispatch,
            gates: sub.live_gates().to_vec(),
            completions: std::mem::take(&mut completions[slot]),
        };
        // Window-level conservation, per lane: everything offered is
        // served, shed, or carried forward.
        if fold.carried_in + fold.stream_arrived != fold.served + fold.dropped + fold.carry.len() {
            return Err(Error::SimInvariant(format!(
                "machine {} lane {} lost requests in window at {:.6}s: \
                 {} carried + {} arrived != {} served + {} dropped + {} carried out",
                job.machine,
                lane.lane,
                job.start,
                fold.carried_in,
                fold.stream_arrived,
                fold.served,
                fold.dropped,
                fold.carry.len()
            )));
        }
        lanes.push(fold);
    }

    Ok(MachineFold {
        machine: job.machine,
        makespan: out.makespan.0,
        trace: out.trace,
        total_bytes: out.total_bytes,
        lanes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tiny_cnn;
    use crate::serve::ArrivalProcess;

    fn knl() -> AcceleratorConfig {
        AcceleratorConfig::knl_7210()
    }

    fn job_over<'a>(admit: &'a [f64], horizon: Option<f64>) -> WindowJob<'a> {
        let set = PartitionSet::build_slice(&knl(), &tiny_cnn(), 64, 2, 0, true).unwrap();
        WindowJob {
            machine: 0,
            accel: knl(),
            policy: DispatchPolicy::ShortestQueue,
            stagger: StaggerPolicy::UniformPhase,
            batch_timeout_ms: 0.0,
            stagger_rearm: true,
            rearm_quantile: 0.95,
            start: 0.0,
            horizon,
            lanes: vec![LaneJob {
                lane: 0,
                set: Arc::new(set),
                queue_cap: 0,
                slo_ms: 0.0,
                admit,
                range: 0..admit.len(),
                carry: Vec::new(),
                gap_carry: Vec::new(),
                last_dispatch: None,
                gates: Vec::new(),
            }],
        }
    }

    #[test]
    fn drain_window_serves_the_whole_stream() {
        let admit = ArrivalProcess::poisson(400.0).generate(0.05, 11).unwrap();
        let fold = run_machine_window(&job_over(&admit, None)).unwrap();
        assert_eq!(fold.lanes.len(), 1);
        let lane = &fold.lanes[0];
        assert_eq!(lane.served + lane.dropped, admit.len());
        assert!(lane.carry.is_empty(), "drain window must not carry");
        assert_eq!(lane.completions.len(), lane.served);
        assert!(fold.makespan > 0.0);
        assert!(fold.total_bytes > 0.0);
    }

    #[test]
    fn bounded_window_carries_the_tail() {
        let admit = ArrivalProcess::poisson(2000.0).generate(0.05, 11).unwrap();
        let fold = run_machine_window(&job_over(&admit, Some(0.004))).unwrap();
        let lane = &fold.lanes[0];
        // An overloaded 4 ms window cannot serve a 50 ms stream.
        assert!(!lane.carry.is_empty());
        assert_eq!(
            lane.carried_in + lane.stream_arrived,
            lane.served + lane.dropped + lane.carry.len()
        );
    }
}
