//! The fleet front door: deterministic request routing.
//!
//! The router runs as a *pre-simulation* pass over the seeded arrival
//! stream: because the open-loop arrivals and the failure schedule are
//! both known up front, every request's target machine can be assigned
//! before any engine window runs. Load awareness comes from a fluid
//! backlog model — each machine drains its queue at its roofline
//! capacity, so the expected wait at time `t` is `backlog / capacity` —
//! which is exactly the statistical-shaping argument of the paper lifted
//! one level up: the same smoothing that staggered partitions give a
//! memory bus, load-aware routing gives a fleet.

use crate::error::{Error, Result};
use crate::util::rng::Xoshiro256StarStar;

/// How the front door spreads arrivals over the machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle through the up machines in index order. Load-blind: a slow
    /// machine gets the same share as a fast one.
    RoundRobin,
    /// Send each request to the machine with the smallest expected wait
    /// (fluid backlog over roofline capacity). Needs global state.
    JoinShortestQueue,
    /// Sample two distinct machines uniformly and pick the less loaded —
    /// the classic "power of two choices", which captures most of JSQ's
    /// benefit with two probes instead of a global scan.
    PowerOfTwoChoices,
}

impl RouterPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round_robin",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::PowerOfTwoChoices => "po2c",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "round_robin" | "rr" => Ok(Self::RoundRobin),
            "jsq" | "join_shortest_queue" => Ok(Self::JoinShortestQueue),
            "po2c" | "power_of_two" | "power_of_two_choices" => Ok(Self::PowerOfTwoChoices),
            other => Err(Error::Usage(format!(
                "unknown router policy '{other}' (round_robin|jsq|po2c)"
            ))),
        }
    }
}

/// Seed-deterministic router state. `capacity[i]` is machine `i`'s
/// roofline throughput in img/s; the fluid backlog decays at that rate
/// between arrivals.
#[derive(Debug, Clone)]
pub(crate) struct Router {
    policy: RouterPolicy,
    rng: Xoshiro256StarStar,
    rr_next: usize,
    backlog: Vec<f64>,
    capacity: Vec<f64>,
    last_t: f64,
}

impl Router {
    pub(crate) fn new(policy: RouterPolicy, seed: u64, capacity: Vec<f64>) -> Self {
        assert!(!capacity.is_empty());
        Self {
            policy,
            rng: Xoshiro256StarStar::seed_from_u64(seed),
            rr_next: 0,
            backlog: vec![0.0; capacity.len()],
            capacity,
            last_t: 0.0,
        }
    }

    /// Expected wait at machine `i` under the fluid model.
    fn wait(&self, i: usize) -> f64 {
        self.backlog[i] / self.capacity[i].max(f64::MIN_POSITIVE)
    }

    /// Route one arrival at time `t` to an up machine, or `None` when
    /// the whole fleet is down. Mutates the fluid backlog.
    pub(crate) fn route(&mut self, t: f64, up: &[bool]) -> Option<usize> {
        assert_eq!(up.len(), self.capacity.len());
        let dt = (t - self.last_t).max(0.0);
        self.last_t = t;
        for (b, &c) in self.backlog.iter_mut().zip(&self.capacity) {
            *b = (*b - c * dt).max(0.0);
        }
        let live: Vec<usize> = (0..up.len()).filter(|&i| up[i]).collect();
        if live.is_empty() {
            return None;
        }
        let pick = match self.policy {
            RouterPolicy::RoundRobin => {
                // Cycle over *machine indices* so the rotation is stable
                // across failure epochs, skipping down machines.
                let mut pick = None;
                for _ in 0..up.len() {
                    let i = self.rr_next % up.len();
                    self.rr_next = (self.rr_next + 1) % up.len();
                    if up[i] {
                        pick = Some(i);
                        break;
                    }
                }
                pick.unwrap_or(live[0])
            }
            RouterPolicy::JoinShortestQueue => {
                let mut best = live[0];
                for &i in &live[1..] {
                    if self.wait(i) < self.wait(best) {
                        best = i;
                    }
                }
                best
            }
            RouterPolicy::PowerOfTwoChoices => {
                let a = live[self.rng.next_below(live.len() as u64) as usize];
                if live.len() == 1 {
                    a
                } else {
                    let mut b = a;
                    while b == a {
                        b = live[self.rng.next_below(live.len() as u64) as usize];
                    }
                    if self.wait(b) < self.wait(a) {
                        b
                    } else {
                        a
                    }
                }
            }
        };
        self.backlog[pick] += 1.0;
        Some(pick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<f64> {
        vec![100.0; n]
    }

    #[test]
    fn policy_names_round_trip() {
        let policies = [
            RouterPolicy::RoundRobin,
            RouterPolicy::JoinShortestQueue,
            RouterPolicy::PowerOfTwoChoices,
        ];
        for p in policies {
            assert_eq!(RouterPolicy::from_name(p.name()).unwrap(), p);
        }
        assert_eq!(RouterPolicy::from_name("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(
            RouterPolicy::from_name("power_of_two").unwrap(),
            RouterPolicy::PowerOfTwoChoices
        );
        assert!(RouterPolicy::from_name("nope").is_err());
    }

    #[test]
    fn round_robin_cycles_and_skips_down_machines() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 1, uniform(3));
        let up = vec![true; 3];
        let picks: Vec<usize> = (0..6).map(|k| r.route(k as f64 * 1e-3, &up).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        let down = vec![true, false, true];
        let picks: Vec<usize> =
            (0..4).map(|k| r.route(0.01 + k as f64 * 1e-3, &down).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn jsq_prefers_the_faster_machine_under_load() {
        // Machine 0 drains 4× faster; a burst of simultaneous arrivals
        // should land there 4:1-ish, never all on the slow one.
        let mut r = Router::new(RouterPolicy::JoinShortestQueue, 1, vec![400.0, 100.0]);
        let up = vec![true; 2];
        let mut counts = [0usize; 2];
        for _ in 0..100 {
            counts[r.route(0.0, &up).unwrap()] += 1;
        }
        assert!(counts[0] > counts[1] * 3, "{counts:?}");
    }

    #[test]
    fn po2c_is_seed_deterministic_and_spreads_load() {
        let seq = |seed: u64| -> Vec<usize> {
            let mut r = Router::new(RouterPolicy::PowerOfTwoChoices, seed, uniform(3));
            let up = vec![true; 3];
            (0..64).map(|k| r.route(k as f64 * 1e-4, &up).unwrap()).collect()
        };
        assert_eq!(seq(7), seq(7));
        assert_ne!(seq(7), seq(8));
        let picks = seq(7);
        for m in 0..3 {
            assert!(picks.iter().filter(|&&p| p == m).count() > 0);
        }
    }

    #[test]
    fn all_down_routes_nowhere() {
        let mut r = Router::new(RouterPolicy::JoinShortestQueue, 1, uniform(2));
        assert_eq!(r.route(0.0, &[false, false]), None);
        assert_eq!(r.route(0.0, &[false, true]), Some(1));
    }
}
