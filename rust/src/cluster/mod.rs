//! Fleet-scale serving: many machines behind one front door.
//!
//! The paper's argument is statistical: asynchronous partitions shape a
//! single accelerator's DRAM traffic because independent phases rarely
//! peak together. A cluster is the same argument one level up — machines
//! fluctuate independently, so fleet bandwidth adds in mean but only in
//! root-sum-square in deviation, and a load-aware router smooths the
//! arrival process each machine sees. This module makes that measurable:
//!
//! * [`MachineConfig`] — one heterogeneous machine: a core count, a
//!   memory-bandwidth scale on the base accelerator, and its own
//!   [`ServeConfig`] for serving knobs;
//! * [`RouterPolicy`] — the front door: round-robin, join-shortest-queue
//!   or power-of-two-choices over a fluid backlog model, all
//!   seed-deterministic;
//! * placed mode — fleet-level tenants ([`ServeConfig::tenants`] on the
//!   cluster config) are bin-packed onto machines by share, under the
//!   machine-wide joint DRAM footprint
//!   ([`crate::sim::DramModel::check_joint`]); failures migrate tenants
//!   (weight-transfer bytes charged to the target), restarts migrate
//!   them home;
//! * [`FailureEvent`] — machines fail mid-run and optionally restart;
//!   backlog drains to the survivors through the same carry/splice path
//!   the epoch engine uses, and per-machine request conservation
//!   (`routed + re_routed_in == served + dropped + re_routed_out`) is
//!   enforced as a [`crate::error::Error::SimInvariant`];
//! * [`ClusterOutcome`] — per-machine and fleet rows: availability,
//!   throughput, goodput, latency percentiles, bandwidth mean/σ, and
//!   the migration ledger.
//!
//! Machines between failure boundaries are independent engine runs, so
//! each window fans out over the sweep thread pool
//! ([`crate::sweep`]'s `parallel_map`) and folds back in machine order —
//! reports are byte-identical for any `--threads`. With
//! `serve.replications > 1` the whole fleet run repeats under
//! [`crate::sweep::ReplicationPlan`]-derived seeds and every report row
//! gains mean ± 95% CI columns (see [`ClusterOutcome::csv_columns`]);
//! replication 0 keeps the base seed, so a replicated run's headline
//! numbers match the single-run report exactly.

mod machine;
mod outcome;
mod placement;
mod router;

pub use outcome::{ClusterOutcome, MachineReport};
pub use placement::Migration;
pub use router::RouterPolicy;

use machine::{Lane, LaneJob, MachineState, WindowJob};
use placement::{hosted_cores, migration_bytes, pick_host, place_all};
use router::Router;

use crate::config::AcceleratorConfig;
use crate::error::{Error, Result};
use crate::model::Graph;
use crate::serve::{roofline_capacity_ips, LatencyRecorder, PartitionSet, ServeConfig};
use crate::sweep::{parallel_map, ReplicatedMetrics};
use crate::util::units::Seconds;
use std::collections::BTreeMap;
use std::sync::Arc;

/// One machine of the fleet: its size, its relative memory bandwidth,
/// and its serving knobs.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub cores: usize,
    /// Memory bandwidth relative to the base accelerator (0.5 = half).
    pub bw_scale: f64,
    /// Per-machine serving knobs. In routed mode the machine serves
    /// `serve.headline_partitions()` partitions with these queue/SLO
    /// settings; fleet-level knobs (arrival, rate, duration, seed,
    /// tenants) live on [`ClusterConfig::serve`].
    pub serve: ServeConfig,
}

impl MachineConfig {
    pub fn new(cores: usize) -> Self {
        Self { cores, bw_scale: 1.0, serve: ServeConfig::default() }
    }

    pub fn bw_scale(mut self, s: f64) -> Self {
        self.bw_scale = s;
        self
    }

    /// This machine's accelerator: the base config resized and scaled.
    pub fn accel(&self, base: &AcceleratorConfig, index: usize) -> AcceleratorConfig {
        let mut a = base.clone();
        a.name = format!("{}/m{index}", base.name);
        a.cores = self.cores;
        a.mem_bw = crate::util::units::BytesPerS(base.mem_bw.0 * self.bw_scale);
        a
    }

    /// Parse `CORES[:BW_SCALE],...` — e.g. `64:1.0,32:0.5,16` (scale
    /// defaults to 1).
    pub fn parse_list(spec: &str) -> Result<Vec<MachineConfig>> {
        let mut out = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let mut it = part.splitn(2, ':');
            let cores: usize = it
                .next()
                .unwrap_or_default()
                .parse()
                .map_err(|_| Error::Usage(format!("bad machine cores in '{part}'")))?;
            let bw_scale = match it.next() {
                Some(s) => s
                    .parse::<f64>()
                    .map_err(|_| Error::Usage(format!("bad machine bw scale in '{part}'")))?,
                None => 1.0,
            };
            out.push(MachineConfig::new(cores).bw_scale(bw_scale));
        }
        if out.is_empty() {
            return Err(Error::Usage(format!("no machines in '{spec}'")));
        }
        Ok(out)
    }
}

/// One machine failure, optionally followed by a restart.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    pub machine: usize,
    pub at_s: f64,
    /// `None` = the machine stays down for the rest of the run.
    pub restart_s: Option<f64>,
}

impl FailureEvent {
    /// Parse `MACHINE@AT_S[:RESTART_S],...` — e.g. `0@0.1:0.3,2@0.2`.
    pub fn parse_list(spec: &str) -> Result<Vec<FailureEvent>> {
        let mut out = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (m, times) = part
                .split_once('@')
                .ok_or_else(|| Error::Usage(format!("failure '{part}' is not M@T[:RESTART]")))?;
            let machine: usize =
                m.parse().map_err(|_| Error::Usage(format!("bad failure machine in '{part}'")))?;
            let mut it = times.splitn(2, ':');
            let at_s: f64 = it
                .next()
                .unwrap_or_default()
                .parse()
                .map_err(|_| Error::Usage(format!("bad failure time in '{part}'")))?;
            let restart_s = match it.next() {
                Some(s) => Some(
                    s.parse::<f64>()
                        .map_err(|_| Error::Usage(format!("bad restart time in '{part}'")))?,
                ),
                None => None,
            };
            out.push(FailureEvent { machine, at_s, restart_s });
        }
        Ok(out)
    }
}

/// The whole fleet: machines, front door, failure schedule, and the
/// fleet-level serving scenario.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub machines: Vec<MachineConfig>,
    pub router: RouterPolicy,
    pub failures: Vec<FailureEvent>,
    /// Fleet-level serving scenario: arrival family, headline rate,
    /// duration, seed, capacity enforcement and trace sampling — and,
    /// when `serve.tenants` is non-empty, the *placed* mode: tenants are
    /// bin-packed onto machines instead of routing one shared stream.
    pub serve: ServeConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            machines: vec![MachineConfig::new(64), MachineConfig::new(64)],
            router: RouterPolicy::PowerOfTwoChoices,
            failures: Vec::new(),
            serve: ServeConfig::default(),
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<()> {
        if self.machines.is_empty() {
            return Err(Error::InvalidConfig("cluster needs at least one machine".into()));
        }
        for (m, mc) in self.machines.iter().enumerate() {
            if mc.cores == 0 {
                return Err(Error::InvalidConfig(format!("machine {m} has zero cores")));
            }
            if !(mc.bw_scale.is_finite() && mc.bw_scale > 0.0) {
                return Err(Error::InvalidConfig(format!(
                    "machine {m} bw scale must be finite and > 0: {}",
                    mc.bw_scale
                )));
            }
            mc.serve.validate()?;
        }
        self.serve.validate()?;
        if !(self.serve.duration_s > 0.0) {
            return Err(Error::InvalidConfig("cluster serve duration must be > 0 s".into()));
        }
        if self.serve.tenants.is_empty() && !(self.serve.headline_rate() > 0.0) {
            return Err(Error::InvalidConfig(
                "routed cluster mode needs a positive arrival rate".into(),
            ));
        }
        let n = self.machines.len();
        let mut seen = vec![false; n];
        for f in &self.failures {
            if f.machine >= n {
                return Err(Error::InvalidConfig(format!(
                    "failure targets machine {} of {n}",
                    f.machine
                )));
            }
            if seen[f.machine] {
                return Err(Error::InvalidConfig(format!(
                    "machine {} fails more than once (one failure per machine)",
                    f.machine
                )));
            }
            seen[f.machine] = true;
            if !(f.at_s.is_finite() && f.at_s > 0.0 && f.at_s < self.serve.duration_s) {
                return Err(Error::InvalidConfig(format!(
                    "failure time must fall inside the arrival window (0, {}): {}",
                    self.serve.duration_s, f.at_s
                )));
            }
            if let Some(r) = f.restart_s {
                if !(r.is_finite() && r > f.at_s) {
                    return Err(Error::InvalidConfig(format!(
                        "restart must come after the failure at {}: {r}",
                        f.at_s
                    )));
                }
            }
        }
        // Some machine must be up in every inter-boundary window.
        let mut bounds: Vec<f64> = vec![0.0];
        for f in &self.failures {
            bounds.push(f.at_s);
            if let Some(r) = f.restart_s {
                bounds.push(r);
            }
        }
        bounds.sort_by(f64::total_cmp);
        bounds.dedup();
        for &b in &bounds {
            let any_up = (0..n).any(|m| up_at(&self.failures, m, b));
            if !any_up {
                return Err(Error::InvalidConfig(format!(
                    "the whole fleet is down from t = {b}s — nothing can serve"
                )));
            }
        }
        Ok(())
    }
}

/// Is machine `m` up at time `t` (given the failure schedule)?
fn up_at(failures: &[FailureEvent], m: usize, t: f64) -> bool {
    !failures
        .iter()
        .any(|f| f.machine == m && t >= f.at_s && f.restart_s.map_or(true, |r| t < r))
}

/// Per-tenant stream seeds, decorrelated from each other (mirrors the
/// multi-tenant simulator's seeding so a tenant sees the same stream on
/// one machine or on a fleet).
fn tenant_seed(seed: u64, i: usize) -> u64 {
    seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1)
}

/// The cluster simulator: a base accelerator, a model, and a
/// [`ClusterConfig`].
#[derive(Debug, Clone)]
pub struct ClusterSimulator {
    accel: AcceleratorConfig,
    /// The fleet-wide model served in routed mode (placed mode takes
    /// each tenant's own model instead).
    graph: Graph,
    cfg: ClusterConfig,
    threads: usize,
}

impl ClusterSimulator {
    pub fn new(accel: &AcceleratorConfig, graph: &Graph) -> Self {
        Self::from_config(accel, graph, ClusterConfig::default())
    }

    pub fn from_config(accel: &AcceleratorConfig, graph: &Graph, cfg: ClusterConfig) -> Self {
        Self { accel: accel.clone(), graph: graph.clone(), cfg, threads: 1 }
    }

    /// Worker-thread pool for the per-machine window fan-out (0 = all
    /// hardware threads). Results are byte-identical for any value.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = if n == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            n
        };
        self
    }

    /// Run the fleet to drain. With `serve.replications > 1` the whole
    /// fleet run repeats once per [`crate::sweep::ReplicationPlan`]
    /// seed; replication 0 (the base seed) stays the headline outcome —
    /// byte-identical to a single run — and every machine row plus the
    /// fleet row gains a mean ± 95% CI fold. Replications run serially:
    /// each run already fans its machine windows over the thread pool.
    pub fn run(&self) -> Result<ClusterOutcome> {
        self.cfg.validate()?;
        let seeds = self.cfg.serve.replication_plan().seeds();
        if seeds.len() == 1 {
            return self.run_with_seed(seeds[0]);
        }
        let mut runs = Vec::with_capacity(seeds.len());
        for &s in &seeds {
            runs.push(self.run_with_seed(s)?);
        }
        let confidence = self.cfg.serve.confidence;
        let machine_stats: Vec<ReplicatedMetrics> = (0..self.cfg.machines.len())
            .map(|m| {
                let rows: Vec<[f64; 6]> = runs.iter().map(|o| o.machines[m].metric_row()).collect();
                ReplicatedMetrics::from_rows_at(&rows, confidence)
            })
            .collect();
        let fleet_rows: Vec<[f64; 6]> = runs.iter().map(|o| o.fleet.metric_row()).collect();
        let fleet_stats = ReplicatedMetrics::from_rows_at(&fleet_rows, confidence);
        // staticcheck: allow(R3) -- seeds.len() > 1 on this path
        let mut head = runs.into_iter().next().expect("at least one replication");
        for (r, s) in head.machines.iter_mut().zip(machine_stats) {
            r.stats = Some(s);
        }
        head.fleet.stats = Some(fleet_stats);
        Ok(head)
    }

    /// One full fleet run under one seed (router RNG, routed arrival
    /// stream, and per-tenant streams all derive from it).
    fn run_with_seed(&self, seed: u64) -> Result<ClusterOutcome> {
        let n = self.cfg.machines.len();
        let duration = self.cfg.serve.duration_s;
        let placed = !self.cfg.serve.tenants.is_empty();
        let accels: Vec<AcceleratorConfig> =
            self.cfg.machines.iter().enumerate().map(|(m, mc)| mc.accel(&self.accel, m)).collect();

        // ---- Streams and lanes -------------------------------------
        let mut lanes: Vec<Lane> = Vec::new();
        let mut admit: Vec<Vec<f64>> = Vec::new();
        let mut born: Vec<Vec<f64>> = Vec::new();
        let mut hosting: Vec<Vec<usize>> = vec![Vec::new(); n];
        // The router lives for the whole run so failure-time re-routes
        // continue its backlog model and RNG stream.
        let mut router = if placed {
            None
        } else {
            let capacity: Vec<f64> =
                accels.iter().map(|a| roofline_capacity_ips(a, &self.graph)).collect();
            Some(Router::new(self.cfg.router, seed, capacity))
        };

        if placed {
            for (i, t) in self.cfg.serve.tenants.iter().enumerate() {
                let stream = t.arrival.generate(duration, tenant_seed(seed, i))?;
                let mut lane = Lane::new(t.graph.clone(), 0);
                lane.partitions = t.partitions;
                lane.queue_cap = t.queue_cap;
                lane.slo_ms = t.slo_ms;
                lane.share = t.share;
                lanes.push(lane);
                born.push(stream.clone());
                admit.push(stream);
            }
            place_all(&mut lanes, &mut hosting, &accels, self.cfg.serve.enforce_capacity)?;
        } else {
            // Routed mode: one lane per machine over the fleet model.
            for (m, mc) in self.cfg.machines.iter().enumerate() {
                let mut lane = Lane::new(self.graph.clone(), m);
                lane.partitions = mc.serve.headline_partitions();
                lane.queue_cap = mc.serve.queue_cap;
                lane.slo_ms = mc.serve.slo_ms;
                lanes.push(lane);
                hosting[m].push(m);
                admit.push(Vec::new());
                born.push(Vec::new());
            }
            let rate = self.cfg.serve.headline_rate();
            let stream = self.cfg.serve.arrival.process(rate).generate(duration, seed)?;
            // staticcheck: allow(R3) -- placed mode never reaches here
            let router = router.as_mut().expect("routed mode has a router");
            for &t in &stream {
                let up: Vec<bool> = (0..n).map(|m| up_at(&self.cfg.failures, m, t)).collect();
                let Some(m) = router.route(t, &up) else {
                    return Err(Error::SimInvariant(format!(
                        "no machine up for arrival at {t:.6}s (validation should reject this)"
                    )));
                };
                admit[m].push(t);
                born[m].push(t);
            }
        }
        let requests: usize = admit.iter().map(Vec::len).sum();
        // Requests a lane handed off at its machine's failure (routed
        // mode; lane-level conservation needs them).
        let mut re_routed_away: Vec<usize> = vec![0; lanes.len()];

        // ---- Windows between failure boundaries --------------------
        let mut bounds: Vec<f64> = Vec::new();
        for f in &self.cfg.failures {
            bounds.push(f.at_s);
            if let Some(r) = f.restart_s {
                bounds.push(r);
            }
        }
        bounds.sort_by(f64::total_cmp);
        bounds.dedup();

        let mut machines: Vec<MachineState> = (0..n).map(|_| MachineState::new()).collect();
        let mut migrations: Vec<Migration> = Vec::new();
        let mut fleet_makespan = 0.0f64;
        let mut start = 0.0f64;
        // Installed topologies, shared across windows: a lane's slice is
        // recompiled only when its hosting actually changes (the key is
        // everything `build_slice` depends on), not once per window.
        let mut set_cache: BTreeMap<(usize, usize, usize, usize), Arc<PartitionSet>> =
            BTreeMap::new();

        for w in 0..=bounds.len() {
            let horizon = bounds.get(w).copied();
            let cut = horizon.unwrap_or(f64::INFINITY);

            let mut jobs: Vec<WindowJob<'_>> = Vec::new();
            for m in 0..n {
                if !up_at(&self.cfg.failures, m, start) || hosting[m].is_empty() {
                    continue;
                }
                let cores = hosted_cores(&lanes, &hosting[m], accels[m].cores);
                let mc = &self.cfg.machines[m];
                let mut lane_jobs: Vec<LaneJob<'_>> = Vec::new();
                for (slot, &li) in hosting[m].iter().enumerate() {
                    let lane = &lanes[li];
                    let upper = admit[li].partition_point(|&a| a < cut);
                    if lane.carry.is_empty() && upper == lane.cursor {
                        continue; // nothing to do this window
                    }
                    let key = (li, m, cores[slot], lane.partitions);
                    let set = match set_cache.get(&key) {
                        Some(s) => s.clone(),
                        None => {
                            let built = Arc::new(PartitionSet::build_slice(
                                &accels[m],
                                &lane.graph,
                                cores[slot],
                                lane.partitions,
                                mc.serve.max_batch,
                                self.cfg.serve.enforce_capacity,
                            )?);
                            set_cache.insert(key, built.clone());
                            built
                        }
                    };
                    lane_jobs.push(LaneJob {
                        lane: li,
                        set,
                        queue_cap: lane.queue_cap,
                        slo_ms: lane.slo_ms,
                        admit: &admit[li],
                        range: lane.cursor..upper,
                        carry: lane.carry.clone(),
                        gap_carry: lane.gap_carry.clone(),
                        last_dispatch: lane.last_dispatch,
                        gates: lane.gates.clone(),
                    });
                }
                if lane_jobs.is_empty() {
                    continue;
                }
                jobs.push(WindowJob {
                    machine: m,
                    accel: accels[m].clone(),
                    policy: mc.serve.policy,
                    stagger: mc.serve.stagger,
                    batch_timeout_ms: mc.serve.batch_timeout_ms,
                    stagger_rearm: mc.serve.stagger_rearm,
                    rearm_quantile: mc.serve.rearm_quantile,
                    start,
                    horizon,
                    lanes: lane_jobs,
                });
            }

            let folds = parallel_map(&jobs, self.threads, machine::run_machine_window)?;
            drop(jobs);

            // Fold sequentially in machine order (jobs were built in
            // machine order, parallel_map preserves it).
            for fold in folds {
                let m = fold.machine;
                fleet_makespan = fleet_makespan.max(fold.makespan);
                let end = horizon.unwrap_or(fold.makespan).max(fold.makespan);
                let mut tr = fold.trace;
                tr.truncate_to(end);
                machines[m].trace.append_clipped(&tr);
                machines[m].total_bytes += fold.total_bytes;
                for lf in fold.lanes {
                    let lane = &mut lanes[lf.lane];
                    machines[m].routed += lf.stream_arrived - lane.spliced_pending;
                    lane.spliced_pending = 0;
                    lane.cursor += lf.stream_arrived;
                    machines[m].served += lf.served;
                    machines[m].dropped += lf.dropped;
                    machines[m].batches += lf.batches;
                    machines[m].queue_peak = machines[m].queue_peak.max(lf.queue_peak);
                    lane.served += lf.served;
                    lane.dropped += lf.dropped;
                    for (r, finish) in lf.completions {
                        let b = born[lf.lane][r];
                        machines[m].recorder.record(b, finish);
                        let slo_s = Seconds::from_ms(lane.slo_ms).value();
                        if lane.slo_ms == 0.0 || finish - b <= slo_s {
                            machines[m].slo_hits += 1;
                        }
                    }
                    machines[m].recorder.record_drops(lf.dropped);
                    lane.carry = lf.carry;
                    lane.gap_carry = lf.gap_carry;
                    lane.last_dispatch = lf.last_dispatch;
                    lane.gates = lf.gates;
                }
            }

            // ---- Boundary events -----------------------------------
            let Some(b) = horizon else { break };
            let up_after: Vec<bool> = (0..n).map(|m| up_at(&self.cfg.failures, m, b)).collect();

            for f in &self.cfg.failures {
                if f.at_s == b {
                    let m = f.machine;
                    machines[m].failed = true;
                    let hosted: Vec<usize> = hosting[m].clone();
                    if placed {
                        for li in placement::demand_order(&lanes, &hosted) {
                            hosting[m].retain(|&x| x != li);
                            match pick_host(
                                &lanes,
                                li,
                                &hosting,
                                &accels,
                                &up_after,
                                self.cfg.serve.enforce_capacity,
                            ) {
                                Some(target) => {
                                    let wb = migration_bytes(&lanes[li], accels[target].elem_bytes);
                                    migrations.push(Migration {
                                        tenant: li,
                                        model: lanes[li].graph.name.clone(),
                                        from: m,
                                        to: target,
                                        at_s: b,
                                        weight_bytes: wb,
                                    });
                                    machines[target].migrated_bytes += wb;
                                    machines[target].total_bytes += wb;
                                    let k = lanes[li].carry.len();
                                    machines[m].re_routed_out += k;
                                    machines[target].re_routed_in += k;
                                    hosting[target].push(li);
                                    lanes[li].machine = target;
                                    lanes[li].gates.clear();
                                }
                                None => {
                                    // Nowhere to go: shed the backlog
                                    // and the rest of the stream.
                                    let carry = std::mem::take(&mut lanes[li].carry);
                                    let tail = admit[li].len() - lanes[li].cursor;
                                    machines[m].routed += tail;
                                    machines[m].dropped += carry.len() + tail;
                                    machines[m].recorder.record_drops(carry.len() + tail);
                                    lanes[li].dropped += carry.len() + tail;
                                    lanes[li].cursor = admit[li].len();
                                    lanes[li].gates.clear();
                                    lanes[li].gap_carry.clear();
                                    lanes[li].last_dispatch = None;
                                }
                            }
                        }
                    } else {
                        // Routed mode: the failed machine's backlog
                        // re-enters the front door at the boundary.
                        // staticcheck: allow(R3) -- only routed lanes re-route
                        let router = router.as_mut().expect("routed mode has a router");
                        let li = m; // lane index == machine index
                        let carry = std::mem::take(&mut lanes[li].carry);
                        lanes[li].gap_carry.clear();
                        lanes[li].last_dispatch = None;
                        lanes[li].gates.clear();
                        let mut moves: Vec<Vec<usize>> = vec![Vec::new(); n];
                        for idx in carry {
                            let Some(target) = router.route(b, &up_after) else {
                                return Err(Error::SimInvariant(format!(
                                    "no machine up to absorb machine {m}'s backlog at {b:.6}s"
                                )));
                            };
                            moves[target].push(idx);
                        }
                        for (target, idxs) in moves.into_iter().enumerate() {
                            if idxs.is_empty() {
                                continue;
                            }
                            let k = idxs.len();
                            let vals: Vec<f64> = idxs.iter().map(|&idx| born[li][idx]).collect();
                            let pos = lanes[target].cursor;
                            admit[target].splice(pos..pos, std::iter::repeat(b).take(k));
                            born[target].splice(pos..pos, vals);
                            lanes[target].spliced_pending += k;
                            machines[m].re_routed_out += k;
                            machines[target].re_routed_in += k;
                            re_routed_away[li] += k;
                        }
                    }
                }
                if f.restart_s == Some(b) {
                    let m = f.machine;
                    machines[m].restarted = true;
                    if placed {
                        // Fail-back: hosted-elsewhere tenants whose home
                        // this is return when they still fit.
                        let homecomers: Vec<usize> = (0..lanes.len())
                            .filter(|&li| {
                                lanes[li].home == m
                                    && lanes[li].machine != m
                                    // No point paying weight bytes for a
                                    // lane with no work left (e.g. shed).
                                    && (lanes[li].cursor < admit[li].len()
                                        || !lanes[li].carry.is_empty())
                            })
                            .collect();
                        for li in placement::demand_order(&lanes, &homecomers) {
                            let only_home: Vec<bool> =
                                (0..n).map(|x| x == m && up_after[x]).collect();
                            if pick_host(
                                &lanes,
                                li,
                                &hosting,
                                &accels,
                                &only_home,
                                self.cfg.serve.enforce_capacity,
                            )
                            .is_none()
                            {
                                continue; // does not fit back yet
                            }
                            let from = lanes[li].machine;
                            let wb = migration_bytes(&lanes[li], accels[m].elem_bytes);
                            migrations.push(Migration {
                                tenant: li,
                                model: lanes[li].graph.name.clone(),
                                from,
                                to: m,
                                at_s: b,
                                weight_bytes: wb,
                            });
                            machines[m].migrated_bytes += wb;
                            machines[m].total_bytes += wb;
                            let k = lanes[li].carry.len();
                            machines[from].re_routed_out += k;
                            machines[m].re_routed_in += k;
                            hosting[from].retain(|&x| x != li);
                            hosting[m].push(li);
                            lanes[li].machine = m;
                            lanes[li].gates.clear();
                        }
                    } else {
                        // The resumed machine re-staggers from scratch.
                        lanes[m].gates.clear();
                    }
                }
            }
            start = b;
        }

        // ---- Conservation ------------------------------------------
        for (li, lane) in lanes.iter().enumerate() {
            if lane.served + lane.dropped + re_routed_away[li] != admit[li].len() {
                return Err(Error::SimInvariant(format!(
                    "lane {li} lost requests: {} served + {} dropped + {} re-routed of {}",
                    lane.served,
                    lane.dropped,
                    re_routed_away[li],
                    admit[li].len()
                )));
            }
        }
        for (m, ms) in machines.iter().enumerate() {
            if ms.routed + ms.re_routed_in != ms.served + ms.dropped + ms.re_routed_out {
                return Err(Error::SimInvariant(format!(
                    "machine {m} leaks requests: {} routed + {} in != {} served + {} dropped \
                     + {} out",
                    ms.routed, ms.re_routed_in, ms.served, ms.dropped, ms.re_routed_out
                )));
            }
        }
        let fleet_served: usize = machines.iter().map(|m| m.served).sum();
        let fleet_dropped: usize = machines.iter().map(|m| m.dropped).sum();
        if fleet_served + fleet_dropped != requests {
            return Err(Error::SimInvariant(format!(
                "fleet leaks requests: {fleet_served} served + {fleet_dropped} dropped \
                 of {requests}"
            )));
        }

        // ---- Reports -----------------------------------------------
        let per_s = |k: usize| if fleet_makespan > 0.0 { k as f64 / fleet_makespan } else { 0.0 };
        let samples = self.cfg.serve.trace_samples;
        let mut reports: Vec<MachineReport> = Vec::with_capacity(n);
        let mut agg_recorder = LatencyRecorder::new();
        for (m, ms) in machines.iter().enumerate() {
            agg_recorder.absorb(&ms.recorder);
            let down_s: f64 = self
                .cfg
                .failures
                .iter()
                .filter(|f| f.machine == m)
                .map(|f| (f.restart_s.unwrap_or(duration).min(duration) - f.at_s).max(0.0))
                .sum();
            let status = if ms.restarted {
                "restarted"
            } else if ms.failed {
                "failed"
            } else {
                "up"
            };
            let mut latency = ms.recorder.stats();
            latency.slo_hits = ms.slo_hits;
            reports.push(MachineReport {
                machine: m.to_string(),
                cores: self.cfg.machines[m].cores,
                bw_scale: self.cfg.machines[m].bw_scale,
                status: status.to_string(),
                routed: ms.routed,
                re_routed_in: ms.re_routed_in,
                re_routed_out: ms.re_routed_out,
                served: ms.served,
                dropped: ms.dropped,
                batches: ms.batches,
                queue_peak: ms.queue_peak,
                availability: 1.0 - down_s / duration,
                throughput_ips: per_s(ms.served),
                goodput_ips: per_s(ms.slo_hits),
                latency,
                bw: ms.trace.sampled_summary(samples),
                total_bytes: ms.total_bytes,
                migrated_bytes: ms.migrated_bytes,
                placed_tenants: if placed { hosting[m].clone() } else { Vec::new() },
                stats: None,
            });
        }

        // Fleet aggregate: sums where extensive; pooled percentiles;
        // bandwidth as independent-machine aggregate (means add, σ adds
        // in quadrature — the paper's statistical argument at fleet
        // scale).
        let total_cores: usize = reports.iter().map(|r| r.cores).sum();
        let wmean = |f: &dyn Fn(&MachineReport) -> f64| {
            reports.iter().map(|r| f(r) * r.cores as f64).sum::<f64>() / total_cores.max(1) as f64
        };
        let fleet_slo_hits: usize = machines.iter().map(|m| m.slo_hits).sum();
        let mut fleet_latency = agg_recorder.stats();
        fleet_latency.slo_hits = fleet_slo_hits;
        let fleet_bw = crate::util::stats::Summary {
            count: samples,
            mean: reports.iter().map(|r| r.bw.mean).sum(),
            std: reports.iter().map(|r| r.bw.std.powi(2)).sum::<f64>().sqrt(),
            min: reports.iter().map(|r| r.bw.min).sum(),
            max: reports.iter().map(|r| r.bw.max).sum(),
        };
        let fleet = MachineReport {
            machine: "fleet".to_string(),
            cores: total_cores,
            bw_scale: wmean(&|r| r.bw_scale),
            status: "aggregate".to_string(),
            routed: reports.iter().map(|r| r.routed).sum(),
            re_routed_in: reports.iter().map(|r| r.re_routed_in).sum(),
            re_routed_out: reports.iter().map(|r| r.re_routed_out).sum(),
            served: fleet_served,
            dropped: fleet_dropped,
            batches: reports.iter().map(|r| r.batches).sum(),
            queue_peak: reports.iter().map(|r| r.queue_peak).max().unwrap_or(0),
            availability: wmean(&|r| r.availability),
            throughput_ips: per_s(fleet_served),
            goodput_ips: per_s(fleet_slo_hits),
            latency: fleet_latency,
            bw: fleet_bw,
            total_bytes: reports.iter().map(|r| r.total_bytes).sum(),
            migrated_bytes: reports.iter().map(|r| r.migrated_bytes).sum(),
            placed_tenants: Vec::new(),
            stats: None,
        };

        Ok(ClusterOutcome {
            router: self.cfg.router,
            machines: reports,
            fleet,
            migrations,
            requests,
            duration_s: duration,
            makespan_s: fleet_makespan,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tiny_cnn;
    use crate::serve::{ArrivalProcess, TenantSpec};

    fn knl() -> AcceleratorConfig {
        AcceleratorConfig::knl_7210()
    }

    fn small_cfg() -> ClusterConfig {
        let mut cfg = ClusterConfig::default();
        cfg.machines = vec![MachineConfig::new(64), MachineConfig::new(32).bw_scale(0.5)];
        cfg.serve.rates = vec![400.0];
        cfg.serve.duration_s = 0.05;
        cfg.serve.partitions = vec![2];
        cfg
    }

    #[test]
    fn machine_list_parses() {
        let ms = MachineConfig::parse_list("64:1.0, 32:0.5,16").unwrap();
        assert_eq!(ms.len(), 3);
        assert_eq!(ms[0].cores, 64);
        assert_eq!(ms[1].bw_scale, 0.5);
        assert_eq!(ms[2].cores, 16);
        assert_eq!(ms[2].bw_scale, 1.0);
        assert!(MachineConfig::parse_list("").is_err());
        assert!(MachineConfig::parse_list("x:1").is_err());
        let a = ms[1].accel(&knl(), 1);
        assert_eq!(a.cores, 32);
        assert!((a.mem_bw.0 - knl().mem_bw.0 * 0.5).abs() < 1e-3);
    }

    #[test]
    fn failure_list_parses() {
        let fs = FailureEvent::parse_list("0@0.1:0.3,2@0.2").unwrap();
        assert_eq!(fs.len(), 2);
        assert_eq!(fs[0], FailureEvent { machine: 0, at_s: 0.1, restart_s: Some(0.3) });
        assert_eq!(fs[1], FailureEvent { machine: 2, at_s: 0.2, restart_s: None });
        assert!(FailureEvent::parse_list("0:0.1").is_err());
        assert!(FailureEvent::parse_list("a@0.1").is_err());
    }

    #[test]
    fn validation_rejects_malformed_fleets() {
        let mut cfg = small_cfg();
        cfg.machines.clear();
        assert!(cfg.validate().is_err());

        let mut cfg = small_cfg();
        cfg.failures = vec![FailureEvent { machine: 5, at_s: 0.01, restart_s: None }];
        assert!(cfg.validate().is_err());

        let mut cfg = small_cfg();
        cfg.failures = vec![FailureEvent { machine: 0, at_s: 0.2, restart_s: None }];
        assert!(cfg.validate().is_err(), "failure outside the arrival window");

        let mut cfg = small_cfg();
        cfg.failures = vec![FailureEvent { machine: 0, at_s: 0.02, restart_s: Some(0.01) }];
        assert!(cfg.validate().is_err(), "restart before failure");

        // Both machines down at once: nothing can serve.
        let mut cfg = small_cfg();
        cfg.failures = vec![
            FailureEvent { machine: 0, at_s: 0.01, restart_s: None },
            FailureEvent { machine: 1, at_s: 0.02, restart_s: None },
        ];
        assert!(cfg.validate().is_err());

        small_cfg().validate().unwrap();
    }

    #[test]
    fn routed_fleet_conserves_and_reports() {
        let sim = ClusterSimulator::from_config(&knl(), &tiny_cnn(), small_cfg());
        let out = sim.run().unwrap();
        assert!(out.requests > 0);
        assert_eq!(out.fleet.served + out.fleet.dropped, out.requests);
        assert_eq!(out.machines.len(), 2);
        assert!(out.fleet.availability > 0.999);
        assert!(out.fleet.bw.mean > 0.0);
        assert!(out.makespan_s >= out.duration_s * 0.5);
        // Both machines saw traffic under po2c.
        assert!(out.machines.iter().all(|m| m.routed > 0));
        // Deterministic: same config, same result.
        let again = ClusterSimulator::from_config(&knl(), &tiny_cnn(), small_cfg());
        assert_eq!(again.run().unwrap().to_csv().to_string(), out.to_csv().to_string());
    }

    #[test]
    fn run_with_seed_is_deterministic_per_seed() {
        let sim = ClusterSimulator::from_config(&knl(), &tiny_cnn(), small_cfg());
        let a = sim.run_with_seed(7).unwrap();
        let b = sim.run_with_seed(7).unwrap();
        assert_eq!(a.to_csv().to_string(), b.to_csv().to_string());
        let c = sim.run_with_seed(8).unwrap();
        assert!(a.requests > 0 && c.requests > 0);
    }

    #[test]
    fn replicated_cluster_folds_ci_and_keeps_rep0_headline() {
        let base = ClusterSimulator::from_config(&knl(), &tiny_cnn(), small_cfg()).run().unwrap();
        assert!(!base.is_replicated());
        let plain_header = base.to_csv().to_string().lines().next().unwrap().to_string();

        let mut cfg = small_cfg();
        cfg.serve.replications = 3;
        let rep = ClusterSimulator::from_config(&knl(), &tiny_cnn(), cfg.clone()).run().unwrap();
        assert_eq!(rep.replications(), Some(3));
        // Replication 0 runs the base seed: the headline fleet numbers
        // match the single run exactly.
        assert_eq!(rep.fleet.served, base.fleet.served);
        assert_eq!(rep.fleet.dropped, base.fleet.dropped);
        assert_eq!(rep.fleet.latency.p99_ms.to_bits(), base.fleet.latency.p99_ms.to_bits());
        // Every machine row and the fleet row carry a fold, and the CI
        // columns extend the single-run header.
        assert!(rep.machines.iter().all(|m| m.stats.is_some()));
        let csv = rep.to_csv().to_string();
        let header = csv.lines().next().unwrap();
        assert!(header.starts_with(&plain_header));
        assert!(header.contains(",p99_ms_mean,p99_ms_ci95,"));
        assert!(rep.render().contains("p99 ±ci"));
        // Byte-identical across thread counts.
        let t4 = ClusterSimulator::from_config(&knl(), &tiny_cnn(), cfg).threads(4).run().unwrap();
        assert_eq!(t4.to_csv().to_string(), csv);
        assert_eq!(t4.summary_json().to_string_pretty(), rep.summary_json().to_string_pretty());
    }

    #[test]
    fn placed_tenants_land_and_conserve() {
        let mut cfg = small_cfg();
        cfg.serve.rates = Vec::new();
        cfg.serve.tenants = vec![
            TenantSpec::new(tiny_cnn(), 0.6, ArrivalProcess::poisson(300.0)),
            TenantSpec::new(tiny_cnn(), 0.4, ArrivalProcess::poisson(150.0)),
        ];
        let sim = ClusterSimulator::from_config(&knl(), &tiny_cnn(), cfg);
        let out = sim.run().unwrap();
        assert_eq!(out.fleet.served + out.fleet.dropped, out.requests);
        let hosted: usize = out.machines.iter().map(|m| m.placed_tenants.len()).sum();
        assert_eq!(hosted, 2, "every tenant is hosted somewhere");
    }
}
