//! Tenant placement: which machine hosts which lane.
//!
//! Placement is greedy and deterministic: lanes are considered in
//! decreasing share order and each goes to the up machine with the most
//! free cores whose hypothetical hosted set still fits — whole cores
//! (every lane's slice rounded to its partition divisibility) and the
//! machine-wide joint DRAM footprint
//! ([`crate::sim::DramModel::check_joint`], under which same-model
//! tenants share one weight image). The same rule re-places a failed
//! machine's lanes at a failure boundary, each move paying a
//! weight-transfer byte cost on the target machine ([`Migration`]).

use super::machine::Lane;
use crate::config::AcceleratorConfig;
use crate::error::{Error, Result};
use crate::reuse::model_weight_bytes;
use crate::shaping::weighted_cores;
use crate::sim::DramModel;

/// One tenant move between machines, with the weight-transfer bytes the
/// target machine paid for it.
#[derive(Debug, Clone)]
pub struct Migration {
    /// Tenant (lane) index.
    pub tenant: usize,
    pub model: String,
    pub from: usize,
    pub to: usize,
    pub at_s: f64,
    /// Weight image bytes shipped to the target (one copy per
    /// partition, matching the resident-set model).
    pub weight_bytes: f64,
}

/// Whole-core split of one machine over its hosted lanes. Starts from
/// [`weighted_cores`] over the lane shares, then rounds each slice down
/// to a multiple of its partition count (never below one core per
/// partition) so [`crate::serve::PartitionSet::build_slice`] accepts it.
/// Remainder cores idle; the sum may exceed the machine only when the
/// hosted set genuinely does not fit (the caller checks).
pub(crate) fn lane_cores(machine_cores: usize, lanes: &[(f64, usize)]) -> Vec<usize> {
    let shares: Vec<f64> = lanes.iter().map(|&(s, _)| s).collect();
    weighted_cores(machine_cores, &shares)
        .iter()
        .zip(lanes)
        .map(|(&c, &(_, parts))| ((c / parts) * parts).max(parts))
        .collect()
}

/// Core split of machine `m` over the lanes it currently hosts.
pub(crate) fn hosted_cores(lanes: &[Lane], hosting: &[usize], machine_cores: usize) -> Vec<usize> {
    if hosting.is_empty() {
        return Vec::new();
    }
    let specs: Vec<(f64, usize)> =
        hosting.iter().map(|&i| (lanes[i].share, lanes[i].partitions)).collect();
    lane_cores(machine_cores, &specs)
}

/// Does machine `m` fit `hosting ∪ {lane}`? Whole cores and, when
/// capacity is enforced, the machine-wide joint DRAM footprint.
fn fits(
    lanes: &[Lane],
    hosting: &[usize],
    lane: usize,
    accel: &AcceleratorConfig,
    enforce_capacity: bool,
) -> bool {
    let mut hypothetical: Vec<usize> = hosting.to_vec();
    hypothetical.push(lane);
    let cores = hosted_cores(lanes, &hypothetical, accel.cores);
    if cores.iter().sum::<usize>() > accel.cores {
        return false;
    }
    if enforce_capacity {
        let slices: Vec<(&crate::model::Graph, usize, usize)> = hypothetical
            .iter()
            .zip(&cores)
            .map(|(&i, &c)| (&lanes[i].graph, lanes[i].partitions, c))
            .collect();
        if DramModel::new(accel).check_joint(&slices).is_err() {
            return false;
        }
    }
    true
}

/// The host for one lane: the least-loaded up machine that fits it,
/// where load is committed share per core (a 0.5-share tenant weighs a
/// 16-core box four times as heavily as a 64-core one); ties go to the
/// lowest index. `None` when nothing fits.
pub(crate) fn pick_host(
    lanes: &[Lane],
    lane: usize,
    hosting: &[Vec<usize>],
    accels: &[AcceleratorConfig],
    up: &[bool],
    enforce_capacity: bool,
) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None; // (share density after, machine)
    for (m, accel) in accels.iter().enumerate() {
        if !up[m] || !fits(lanes, &hosting[m], lane, accel, enforce_capacity) {
            continue;
        }
        let committed: f64 = hosting[m].iter().map(|&i| lanes[i].share).sum();
        let density = (committed + lanes[lane].share) / accel.cores as f64;
        if best.map_or(true, |(bd, _)| density < bd) {
            best = Some((density, m));
        }
    }
    best.map(|(_, m)| m)
}

/// Deterministic placement order: decreasing share, ties by index.
pub(crate) fn demand_order(lanes: &[Lane], subset: &[usize]) -> Vec<usize> {
    let mut order = subset.to_vec();
    order.sort_by(|&a, &b| lanes[b].share.total_cmp(&lanes[a].share).then(a.cmp(&b)));
    order
}

/// Initial placement of every lane onto the fleet. Mutates each lane's
/// `machine`/`home` and fills `hosting` (machine -> hosted lanes).
pub(crate) fn place_all(
    lanes: &mut [Lane],
    hosting: &mut [Vec<usize>],
    accels: &[AcceleratorConfig],
    enforce_capacity: bool,
) -> Result<()> {
    let up = vec![true; accels.len()];
    let all: Vec<usize> = (0..lanes.len()).collect();
    for i in demand_order(lanes, &all) {
        let Some(m) = pick_host(lanes, i, hosting, accels, &up, enforce_capacity) else {
            return Err(Error::InfeasiblePartitioning(format!(
                "tenant {i} ({}, share {:.3}, {} partitions) fits on no machine",
                lanes[i].graph.name, lanes[i].share, lanes[i].partitions
            )));
        };
        hosting[m].push(i);
        lanes[i].machine = m;
        lanes[i].home = m;
    }
    Ok(())
}

/// The weight-transfer bytes a migration of `lane` ships.
pub(crate) fn migration_bytes(lane: &Lane, elem_bytes: f64) -> f64 {
    model_weight_bytes(&lane.graph, elem_bytes).0 * lane.partitions as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{resnet50, tiny_cnn, vgg16};

    fn lane(graph: crate::model::Graph, share: f64, partitions: usize) -> Lane {
        let mut l = Lane::new(graph, 0);
        l.share = share;
        l.partitions = partitions;
        l
    }

    fn knl(cores: usize) -> AcceleratorConfig {
        let mut a = AcceleratorConfig::knl_7210();
        a.cores = cores;
        a
    }

    #[test]
    fn lane_cores_respects_partition_divisibility() {
        // 64 cores at 60/40: weighted_cores gives 38/26; rounded to the
        // lanes' partition counts (4 and 3) -> 36/24, remainder idle.
        assert_eq!(lane_cores(64, &[(0.6, 4), (0.4, 3)]), vec![36, 24]);
        // Rounding never starves a lane below one core per partition.
        assert_eq!(lane_cores(8, &[(0.9, 1), (0.1, 4)]), vec![7, 4]);
    }

    #[test]
    fn placement_spreads_equal_tenants_over_equal_machines() {
        let mut lanes = vec![lane(tiny_cnn(), 0.5, 1), lane(tiny_cnn(), 0.5, 1)];
        let accels = vec![knl(64), knl(64)];
        let mut hosting = vec![Vec::new(), Vec::new()];
        place_all(&mut lanes, &mut hosting, &accels, true).unwrap();
        assert_ne!(lanes[0].machine, lanes[1].machine);
        assert_eq!(lanes[0].home, lanes[0].machine);
    }

    #[test]
    fn heavy_tenant_lands_on_the_big_machine() {
        let mut lanes = vec![lane(vgg16(), 0.7, 2), lane(resnet50(), 0.3, 1)];
        let accels = vec![knl(16), knl(64)];
        let mut hosting = vec![Vec::new(), Vec::new()];
        place_all(&mut lanes, &mut hosting, &accels, true).unwrap();
        // The 0.7-share lane is placed first and takes the 64-core box.
        assert_eq!(lanes[0].machine, 1);
    }

    #[test]
    fn infeasible_fleet_is_rejected() {
        // A one-machine fleet whose DRAM fits either tenant alone but
        // not both: the first placement passes, the second finds no
        // host. The capacity is picked between the two footprints so
        // the test is arithmetic, not calibration.
        use crate::model::vgg19;
        use crate::util::units::Bytes;
        let d = DramModel::new(&knl(64));
        let (vgg, v19) = (vgg16(), vgg19());
        // Alone, the first-placed tenant owns all 64 cores; together
        // each takes a 32-core slice.
        let alone = d.footprint(&vgg, 8, 64).total().0;
        let joint = d.footprint_joint(&[(&vgg, 8, 32), (&v19, 8, 32)]).total().0;
        assert!(alone < joint);
        let mut a = knl(64);
        a.mem_capacity = Bytes((alone + joint) / 2.0 / d.high_water);
        let mut lanes = vec![lane(vgg, 0.5, 8), lane(v19, 0.5, 8)];
        let mut hosting = vec![Vec::new()];
        let err = place_all(&mut lanes, &mut hosting, &[a], true).unwrap_err();
        assert!(matches!(err, Error::InfeasiblePartitioning(_)), "{err}");
    }

    #[test]
    fn migration_bytes_scale_with_partitions() {
        let l1 = lane(vgg16(), 1.0, 1);
        let l4 = lane(vgg16(), 1.0, 4);
        assert!((migration_bytes(&l4, 4.0) / migration_bytes(&l1, 4.0) - 4.0).abs() < 1e-9);
    }
}
