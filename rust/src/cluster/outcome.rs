//! Cluster run results: per-machine reports plus the fleet aggregate.

use super::placement::Migration;
use super::router::RouterPolicy;
use crate::serve::LatencyStats;
use crate::sweep::ReplicatedMetrics;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;
use crate::util::stats::{Confidence, Summary};
use crate::util::table::Table;
use crate::util::units::Bytes;

/// One machine's (or the fleet's) run accounting.
#[derive(Debug, Clone)]
pub struct MachineReport {
    /// Machine label: the index, or `fleet` for the aggregate row.
    pub machine: String,
    pub cores: usize,
    pub bw_scale: f64,
    /// `up`, `failed` (down at end of run) or `restarted`.
    pub status: String,
    /// Front-door arrivals assigned here.
    pub routed: usize,
    /// Requests inherited from failed machines.
    pub re_routed_in: usize,
    /// Requests handed off at this machine's failure.
    pub re_routed_out: usize,
    pub served: usize,
    pub dropped: usize,
    pub batches: usize,
    pub queue_peak: usize,
    /// Fraction of the arrival window the machine was up (core-weighted
    /// mean for the fleet row).
    pub availability: f64,
    pub throughput_ips: f64,
    pub goodput_ips: f64,
    pub latency: LatencyStats,
    pub bw: Summary,
    pub total_bytes: f64,
    /// Weight-transfer bytes paid for migrations onto this machine.
    pub migrated_bytes: f64,
    /// Tenants hosted at end of run (placed mode; empty when routed).
    pub placed_tenants: Vec<usize>,
    /// Mean ± 95% CI over replications (`None` on single runs).
    pub stats: Option<ReplicatedMetrics>,
}

impl MachineReport {
    pub fn drop_rate(&self) -> f64 {
        let arrived = self.served + self.dropped;
        if arrived == 0 {
            0.0
        } else {
            self.dropped as f64 / arrived as f64
        }
    }

    /// The six headline metrics folded across replications, in the
    /// order `ReplicatedMetrics::from_rows` expects.
    pub(crate) fn metric_row(&self) -> [f64; 6] {
        [
            self.latency.p50_ms,
            self.latency.p95_ms,
            self.latency.p99_ms,
            self.throughput_ips,
            self.goodput_ips,
            self.drop_rate(),
        ]
    }
}

/// Everything a cluster run produces.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    pub router: RouterPolicy,
    pub machines: Vec<MachineReport>,
    /// The fleet aggregate: served/dropped/bytes sum over machines;
    /// latency percentiles over the pooled sojourn record; bandwidth
    /// mean is the sum of machine means and its std the root of the
    /// summed variances (machines fluctuate independently — the paper's
    /// statistical-shaping argument, one level up).
    pub fleet: MachineReport,
    pub migrations: Vec<Migration>,
    /// Front-door arrivals over the whole run.
    pub requests: usize,
    pub duration_s: f64,
    pub makespan_s: f64,
}

impl ClusterOutcome {
    /// True when the run folded more than one replication.
    pub fn is_replicated(&self) -> bool {
        self.fleet.stats.is_some()
    }

    /// Replication count, when the run was replicated.
    pub fn replications(&self) -> Option<usize> {
        self.fleet.stats.as_ref().map(|s| s.replications())
    }

    /// Human-readable per-machine table.
    pub fn render(&self) -> String {
        let replicated = self.is_replicated();
        let mut cols = vec![
            "machine",
            "cores",
            "bw×",
            "status",
            "routed",
            "re-in",
            "re-out",
            "served",
            "drop %",
            "avail %",
            "thr (img/s)",
            "goodput",
            "p99 ms",
        ];
        if replicated {
            cols.push("p99 ±ci");
        }
        cols.extend(["BW GB/s", "mig GB"]);
        let mut t = Table::new(cols)
            .title(&format!("cluster ({} router)", self.router.name()))
            .left_first();
        for r in self.machines.iter().chain(std::iter::once(&self.fleet)) {
            let mut cells = vec![
                r.machine.clone(),
                r.cores.to_string(),
                format!("{:.2}", r.bw_scale),
                r.status.clone(),
                r.routed.to_string(),
                r.re_routed_in.to_string(),
                r.re_routed_out.to_string(),
                r.served.to_string(),
                format!("{:.1}", r.drop_rate() * 100.0),
                format!("{:.1}", r.availability * 100.0),
                format!("{:.0}", r.throughput_ips),
                format!("{:.0}", r.goodput_ips),
                format!("{:.2}", r.latency.p99_ms),
            ];
            if replicated {
                cells.push(r.stats.as_ref().map_or("-".into(), |s| s.p99_ms.render(1)));
            }
            cells.push(format!("{:.1}", r.bw.mean));
            cells.push(format!("{:.2}", Bytes(r.migrated_bytes).gb()));
            t.row(cells);
        }
        t.render()
    }

    /// CSV header for machine rows. With `replicated` the per-metric
    /// `*_mean`/`*_ci95` columns are appended after the base set, so a
    /// single-run header stays a strict prefix of a replicated one.
    pub fn csv_columns(replicated: bool) -> Vec<&'static str> {
        let mut cols = vec![
            "machine",
            "cores",
            "bw_scale",
            "router",
            "status",
            "routed",
            "re_routed_in",
            "re_routed_out",
            "served",
            "dropped",
            "drop_rate",
            "batches",
            "queue_peak",
            "availability",
            "throughput_ips",
            "goodput_ips",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "bw_mean_gbps",
            "bw_std_gbps",
            "total_gb",
            "placed_tenants",
            "migrated_gb",
        ];
        if replicated {
            cols.extend(ReplicatedMetrics::CSV_COLUMNS);
        }
        cols
    }

    /// [`Self::csv_columns`] at an explicit coverage level: identical at
    /// the default 95 %, interval suffixes renamed otherwise.
    pub fn csv_columns_at(replicated: bool, confidence: Confidence) -> Vec<String> {
        let mut cols: Vec<String> =
            Self::csv_columns(false).into_iter().map(str::to_string).collect();
        if replicated {
            cols.extend(ReplicatedMetrics::csv_columns_at(confidence));
        }
        cols
    }

    /// The interval coverage of the replication folds (the default when
    /// the outcome is unreplicated).
    pub fn confidence(&self) -> Confidence {
        self.fleet.stats.as_ref().map(|s| s.confidence()).unwrap_or_default()
    }

    /// One row per machine plus the `fleet` row.
    pub fn to_csv(&self) -> CsvWriter {
        let replicated = self.is_replicated();
        let mut w = CsvWriter::new(Self::csv_columns_at(replicated, self.confidence()));
        let f = crate::util::csv::format_float;
        for r in self.machines.iter().chain(std::iter::once(&self.fleet)) {
            let tenants = r
                .placed_tenants
                .iter()
                .map(|t| format!("t{t}"))
                .collect::<Vec<_>>()
                .join("+");
            let mut cells = vec![
                r.machine.clone(),
                r.cores.to_string(),
                f(r.bw_scale),
                self.router.name().to_string(),
                r.status.clone(),
                r.routed.to_string(),
                r.re_routed_in.to_string(),
                r.re_routed_out.to_string(),
                r.served.to_string(),
                r.dropped.to_string(),
                f(r.drop_rate()),
                r.batches.to_string(),
                r.queue_peak.to_string(),
                f(r.availability),
                f(r.throughput_ips),
                f(r.goodput_ips),
                f(r.latency.p50_ms),
                f(r.latency.p95_ms),
                f(r.latency.p99_ms),
                f(r.bw.mean),
                f(r.bw.std),
                f(Bytes(r.total_bytes).gb()),
                tenants,
                f(Bytes(r.migrated_bytes).gb()),
            ];
            if replicated {
                match &r.stats {
                    Some(s) => cells.extend(s.csv_cells()),
                    None => {
                        let blanks = ReplicatedMetrics::CSV_COLUMNS.len();
                        cells.extend((0..blanks).map(|_| String::new()));
                    }
                }
            }
            w.row(cells);
        }
        w
    }

    /// Machine-readable run summary.
    pub fn summary_json(&self) -> Json {
        let mut migrations = Vec::new();
        for m in &self.migrations {
            migrations.push(
                Json::obj()
                    .with("tenant", m.tenant)
                    .with("model", m.model.as_str())
                    .with("from", m.from)
                    .with("to", m.to)
                    .with("at_s", m.at_s)
                    .with("weight_gb", Bytes(m.weight_bytes).gb()),
            );
        }
        let mut j = Json::obj()
            .with("router", self.router.name())
            .with("machines", self.machines.len())
            .with("requests", self.requests)
            .with("duration_s", self.duration_s)
            .with("makespan_s", self.makespan_s)
            .with("served", self.fleet.served)
            .with("dropped", self.fleet.dropped)
            .with("drop_rate", self.fleet.drop_rate())
            .with("availability", self.fleet.availability)
            .with("throughput_ips", self.fleet.throughput_ips)
            .with("goodput_ips", self.fleet.goodput_ips)
            .with("p50_ms", self.fleet.latency.p50_ms)
            .with("p99_ms", self.fleet.latency.p99_ms)
            .with("bw_mean_gbps", self.fleet.bw.mean)
            .with("bw_std_gbps", self.fleet.bw.std);
        if let Some(s) = &self.fleet.stats {
            let sfx = s.confidence().suffix();
            j.set("replications", s.replications());
            j.set("p99_ms_mean", s.p99_ms.mean);
            j.set(&format!("p99_ms_{sfx}"), s.p99_ms.ci);
            j.set("goodput_ips_mean", s.goodput_ips.mean);
            j.set(&format!("goodput_ips_{sfx}"), s.goodput_ips.ci);
        }
        j.with("migrations", migrations)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(label: &str) -> MachineReport {
        MachineReport {
            machine: label.to_string(),
            cores: 64,
            bw_scale: 1.0,
            status: "up".to_string(),
            routed: 100,
            re_routed_in: 5,
            re_routed_out: 0,
            served: 100,
            dropped: 5,
            batches: 10,
            queue_peak: 4,
            availability: 1.0,
            throughput_ips: 500.0,
            goodput_ips: 480.0,
            latency: crate::serve::LatencyRecorder::new().stats(),
            bw: Summary::of(&[120.0, 180.0]),
            total_bytes: 3e9,
            migrated_bytes: 0.0,
            placed_tenants: vec![0, 2],
            stats: None,
        }
    }

    fn outcome() -> ClusterOutcome {
        ClusterOutcome {
            router: RouterPolicy::PowerOfTwoChoices,
            machines: vec![report("0"), report("1")],
            fleet: report("fleet"),
            migrations: vec![Migration {
                tenant: 0,
                model: "tiny".into(),
                from: 1,
                to: 0,
                at_s: 0.1,
                weight_bytes: 2e6,
            }],
            requests: 210,
            duration_s: 0.5,
            makespan_s: 0.6,
        }
    }

    #[test]
    fn csv_has_the_documented_columns_and_fleet_row() {
        let out = outcome().to_csv().to_string();
        let header = out.lines().next().unwrap();
        for col in ["machine", "router", "re_routed_in", "placed_tenants", "migrated_gb"] {
            assert!(header.split(',').any(|c| c == col), "missing {col} in {header}");
        }
        assert_eq!(out.lines().count(), 4, "2 machines + fleet + header");
        assert!(out.lines().last().unwrap().starts_with("fleet,"));
        assert!(out.contains("po2c"));
        assert!(out.contains("t0+t2"));
    }

    #[test]
    fn replicated_outcome_appends_ci_columns_after_the_base_header() {
        let mut o = outcome();
        let plain_header = o.to_csv().to_string().lines().next().unwrap().to_string();

        o.fleet.stats =
            Some(ReplicatedMetrics::from_rows(&[o.fleet.metric_row(), o.fleet.metric_row()]));
        assert!(o.is_replicated());
        assert_eq!(o.replications(), Some(2));

        let csv = o.to_csv().to_string();
        let header = csv.lines().next().unwrap();
        assert!(header.starts_with(&plain_header), "base header must stay a prefix");
        assert!(header.ends_with(",drop_rate_mean,drop_rate_ci95"));
        // Machine rows carry no fold (stats: None) -> empty CI cells.
        let machine_row = csv.lines().nth(1).unwrap();
        assert!(machine_row.ends_with(&",".repeat(12)), "12 empty CI cells");
        assert!(o.render().contains("p99 ±ci"));

        let j = o.summary_json().to_string_pretty();
        assert!(j.contains("\"replications\": 2"));
        assert!(j.contains("\"p99_ms_ci95\""));
    }

    #[test]
    fn render_and_json_mention_the_router_and_migrations() {
        let o = outcome();
        assert!(o.render().contains("po2c"));
        let j = o.summary_json().to_string_pretty();
        assert!(j.contains("\"router\""));
        assert!(j.contains("\"migrations\""));
        assert!(j.contains("\"weight_gb\""));
    }
}
