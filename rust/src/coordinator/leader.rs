//! The leader: dispatches jobs to partition workers and aggregates the
//! metered traffic into shaping statistics.

use super::metrics::TrafficMeter;
use super::worker::{BatchJob, BatchResult, PartitionWorker};
use crate::error::{Error, Result};
use crate::runtime::Manifest;
use crate::util::rng::Xoshiro256StarStar;
use crate::util::stats::{StepSeries, Summary};
use crate::util::units::Bytes;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifact_dir: PathBuf,
    /// Number of partitions (worker threads).
    pub partitions: usize,
    /// Micro-batch size (must exist in the manifest's `batches`).
    pub micro_batch: usize,
    /// Total micro-batches to process across all partitions.
    pub total_batches: usize,
    /// Verify every compiled artifact against its manifest check vector.
    pub self_check: bool,
    /// Seed for synthetic input images.
    pub seed: u64,
    /// Samples for the bandwidth series statistics.
    pub trace_samples: usize,
}

impl CoordinatorConfig {
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Self {
        Self {
            artifact_dir: artifact_dir.into(),
            partitions: 2,
            micro_batch: 8,
            total_batches: 16,
            self_check: true,
            seed: 42,
            trace_samples: 64,
        }
    }
}

/// Aggregated result of a coordinator run.
#[derive(Debug, Clone)]
pub struct CoordinatorReport {
    pub partitions: usize,
    pub images: usize,
    pub wall_seconds: f64,
    pub throughput_ips: f64,
    /// Metered-traffic bandwidth summary (GB/s over sampled series).
    pub bw: Summary,
    pub total_traffic_bytes: f64,
    /// Per-worker processed job counts.
    pub jobs_per_worker: Vec<usize>,
    /// Checksum over all logits (regression guard: runs with the same
    /// seed must reproduce it exactly).
    pub logits_checksum: f64,
}

/// The leader/worker coordinator.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    manifest: Manifest,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Result<Self> {
        let manifest = Manifest::load(&cfg.artifact_dir)?;
        if !manifest.batches.contains(&cfg.micro_batch) {
            return Err(Error::InvalidConfig(format!(
                "micro_batch {} not in manifest batches {:?}",
                cfg.micro_batch, manifest.batches
            )));
        }
        if cfg.partitions == 0 || cfg.total_batches == 0 {
            return Err(Error::InvalidConfig("partitions and total_batches must be > 0".into()));
        }
        Ok(Self { cfg, manifest })
    }

    /// Deterministic synthetic input batch.
    fn make_input(rng: &mut Xoshiro256StarStar, len: usize) -> Vec<f32> {
        (0..len).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect()
    }

    /// Run the full workload; blocks until all jobs complete.
    #[allow(clippy::disallowed_methods)] // real-execution path: wall-clock origin
    pub fn run(&self) -> Result<CoordinatorReport> {
        let n = self.cfg.partitions;
        let origin = Instant::now();

        // Pre-generate all job inputs (leader-side, deterministic).
        let stage0 = self.manifest.stage(&self.manifest.stage_order[0], self.cfg.micro_batch)?;
        let input_len = stage0.input_elems();
        let mut rng = Xoshiro256StarStar::seed_from_u64(self.cfg.seed);
        let jobs: Vec<BatchJob> = (0..self.cfg.total_batches)
            .map(|id| BatchJob { id, input: Self::make_input(&mut rng, input_len) })
            .collect();

        // Round-robin static assignment (each partition processes its own
        // stream, like the paper's independent instances).
        let mut queues: Vec<Vec<BatchJob>> = vec![Vec::new(); n];
        for (i, job) in jobs.into_iter().enumerate() {
            queues[i % n].push(job);
        }

        let (tx, rx) = mpsc::channel::<Result<BatchResult>>();
        let mut handles = Vec::new();
        for (idx, queue) in queues.into_iter().enumerate() {
            let tx = tx.clone();
            let manifest = self.manifest.clone();
            let micro_batch = self.cfg.micro_batch;
            let self_check = self.cfg.self_check;
            handles.push(std::thread::spawn(move || -> Result<TrafficMeter> {
                let mut worker =
                    PartitionWorker::new(idx, &manifest, micro_batch, origin, self_check)?;
                for job in queue {
                    let result = worker.process(job);
                    let failed = result.is_err();
                    tx.send(result).map_err(|_| {
                        Error::Coordinator("leader hung up".into())
                    })?;
                    if failed {
                        break;
                    }
                }
                Ok(worker.into_meter())
            }));
        }
        drop(tx);

        // Collect results.
        let mut results: Vec<BatchResult> = Vec::with_capacity(self.cfg.total_batches);
        for r in rx {
            results.push(r?);
        }

        // Join workers, collect meters.
        let mut meters = Vec::with_capacity(n);
        for h in handles {
            let meter = h
                .join()
                .map_err(|_| Error::Coordinator("worker panicked".into()))??;
            meters.push(meter);
        }
        let wall = origin.elapsed().as_secs_f64();

        if results.len() != self.cfg.total_batches {
            return Err(Error::Coordinator(format!(
                "lost jobs: {} of {}",
                results.len(),
                self.cfg.total_batches
            )));
        }

        // Aggregate statistics.
        let merged: StepSeries = TrafficMeter::merge(&meters, wall);
        let gbps: Vec<f64> = merged
            .resample(self.cfg.trace_samples)
            .into_iter()
            .map(|b| Bytes(b).gb())
            .collect();
        let mut jobs_per_worker = vec![0usize; n];
        let mut checksum = 0.0f64;
        for r in &results {
            jobs_per_worker[r.worker] += 1;
            checksum += r.logits.iter().map(|&v| v as f64).sum::<f64>();
        }
        let images = self.cfg.total_batches * self.cfg.micro_batch;
        Ok(CoordinatorReport {
            partitions: n,
            images,
            wall_seconds: wall,
            throughput_ips: images as f64 / wall,
            bw: Summary::of(&gbps),
            total_traffic_bytes: meters.iter().map(|m| m.total_bytes()).sum(),
            jobs_per_worker,
            logits_checksum: checksum,
        })
    }
}
