//! Live traffic metering for the real-execution path.

use crate::util::stats::StepSeries;
use std::time::Instant;

/// One metered stage execution: wall-clock interval plus bytes moved
/// (analytic per-stage byte count from the manifest).
#[derive(Debug, Clone, Copy)]
pub struct TrafficEvent {
    pub t0: f64,
    pub t1: f64,
    pub bytes: f64,
}

/// Per-worker traffic recorder. Workers record locally (no contention);
/// the leader merges the meters after the run.
#[derive(Debug)]
pub struct TrafficMeter {
    origin: Instant,
    events: Vec<TrafficEvent>,
}

impl TrafficMeter {
    /// `origin` is shared across all workers so timelines align.
    pub fn new(origin: Instant) -> Self {
        Self { origin, events: Vec::new() }
    }

    /// Current time on the shared clock.
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    /// Record a stage execution that started at `t0` (from [`Self::now`])
    /// and just finished, moving `bytes`.
    pub fn record(&mut self, t0: f64, bytes: f64) {
        let t1 = self.now();
        self.events.push(TrafficEvent { t0, t1: t1.max(t0 + 1e-9), bytes });
    }

    pub fn events(&self) -> &[TrafficEvent] {
        &self.events
    }

    /// Convert to a gap-filled bandwidth series over `[0, horizon]`.
    pub fn to_series(&self, horizon: f64) -> StepSeries {
        let mut s = StepSeries::new();
        let mut cursor = 0.0;
        for e in &self.events {
            let (t0, t1) = (e.t0.max(cursor), e.t1.min(horizon).max(e.t0));
            if t0 > cursor {
                s.push(cursor, t0, 0.0);
            }
            if t1 > t0 {
                s.push(t0, t1, e.bytes / (e.t1 - e.t0));
                cursor = t1;
            }
        }
        if cursor < horizon {
            s.push(cursor, horizon, 0.0);
        }
        s
    }

    /// Total bytes recorded.
    pub fn total_bytes(&self) -> f64 {
        self.events.iter().map(|e| e.bytes).sum()
    }

    /// Merge several meters into the aggregate bandwidth series the
    /// "memory controller" of this host saw.
    pub fn merge(meters: &[TrafficMeter], horizon: f64) -> StepSeries {
        let series: Vec<StepSeries> = meters.iter().map(|m| m.to_series(horizon)).collect();
        let refs: Vec<&StepSeries> = series.iter().collect();
        StepSeries::sum(&refs)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // the meter under test is wall-clock based
mod tests {
    use super::*;

    fn meter_with(events: &[(f64, f64, f64)]) -> TrafficMeter {
        let mut m = TrafficMeter::new(Instant::now());
        for &(t0, t1, b) in events {
            m.events.push(TrafficEvent { t0, t1, bytes: b });
        }
        m
    }

    #[test]
    fn series_fills_gaps_and_conserves_bytes() {
        let m = meter_with(&[(0.1, 0.2, 100.0), (0.5, 1.0, 50.0)]);
        let s = m.to_series(1.5);
        assert!((s.integral() - 150.0).abs() < 1e-9);
        assert_eq!(s.start(), 0.0);
        assert_eq!(s.end(), 1.5);
        assert_eq!(s.at(0.05), 0.0);
        assert!((s.at(0.15) - 1000.0).abs() < 1e-9);
        assert_eq!(s.at(1.2), 0.0);
    }

    #[test]
    fn merge_sums_workers() {
        let a = meter_with(&[(0.0, 1.0, 100.0)]);
        let b = meter_with(&[(0.5, 1.5, 100.0)]);
        let merged = TrafficMeter::merge(&[a, b], 2.0);
        assert!((merged.integral() - 200.0).abs() < 1e-9);
        assert!((merged.at(0.75) - 200.0).abs() < 1e-9); // overlap region
        assert!((merged.at(0.25) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn record_uses_wall_clock() {
        let mut m = TrafficMeter::new(Instant::now());
        let t0 = m.now();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.record(t0, 42.0);
        let e = m.events()[0];
        assert!(e.t1 > e.t0);
        assert!((m.total_bytes() - 42.0).abs() < 1e-12);
    }
}
