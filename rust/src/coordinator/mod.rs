//! Threaded partition coordinator — the real-execution twin of the
//! simulator.
//!
//! A leader thread dispatches micro-batch jobs to `n` partition workers;
//! each worker owns an independent [`crate::runtime::RuntimeClient`]
//! (its own PJRT client and compiled executables — one framework
//! instance per partition, exactly the paper's deployment) and runs the
//! TinyCNN pipeline stage by stage, metering the memory traffic of every
//! stage execution. The merged per-partition traffic series gives the
//! same σ/mean bandwidth statistics the simulator produces, measured on
//! real numerics.

mod leader;
mod metrics;
mod worker;

pub use leader::{Coordinator, CoordinatorConfig, CoordinatorReport};
pub use metrics::{TrafficEvent, TrafficMeter};
pub use worker::{BatchJob, BatchResult, PartitionWorker};
