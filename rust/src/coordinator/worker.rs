//! Partition worker: one synchronous core group's execution loop.

use super::metrics::TrafficMeter;
use crate::error::Result;
use crate::runtime::{Manifest, RuntimeClient};
use std::time::Instant;

/// One unit of work: a micro-batch of images (flat NHWC f32).
#[derive(Debug, Clone)]
pub struct BatchJob {
    pub id: usize,
    pub input: Vec<f32>,
}

/// What a worker returns per job.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub id: usize,
    pub worker: usize,
    /// Output logits, flat [batch, classes].
    pub logits: Vec<f32>,
    /// Wall time of the pipeline pass in seconds.
    pub elapsed: f64,
}

/// A partition worker: owns its own PJRT client and compiled pipeline
/// (one independent instance per partition, like the paper's per-
/// partition framework instances).
pub struct PartitionWorker {
    pub index: usize,
    pub micro_batch: usize,
    client: RuntimeClient,
    meter: TrafficMeter,
}

impl PartitionWorker {
    pub fn new(
        index: usize,
        manifest: &Manifest,
        micro_batch: usize,
        origin: Instant,
        self_check: bool,
    ) -> Result<Self> {
        let client = RuntimeClient::new(manifest, micro_batch)?;
        if self_check {
            client.self_check_all()?;
        }
        Ok(Self { index, micro_batch, client, meter: TrafficMeter::new(origin) })
    }

    /// Execute one micro-batch through the full pipeline, metering every
    /// stage's traffic.
    pub fn process(&mut self, job: BatchJob) -> Result<BatchResult> {
        let start = self.meter.now();
        let order = self.client.manifest().stage_order.clone();
        let mut x = job.input;
        for name in &order {
            let t0 = self.meter.now();
            let stage = self.client.stage(name, self.micro_batch)?;
            let bytes = stage.meta.traffic_bytes();
            x = stage.run(&x)?;
            self.meter.record(t0, bytes);
        }
        Ok(BatchResult {
            id: job.id,
            worker: self.index,
            logits: x,
            elapsed: self.meter.now() - start,
        })
    }

    /// Surrender the traffic meter at end of run.
    pub fn into_meter(self) -> TrafficMeter {
        self.meter
    }

    /// Expected flat input length for one job.
    pub fn input_len(&self) -> usize {
        let first = &self.client.manifest().stage_order[0];
        self.client
            .manifest()
            .stage(first, self.micro_batch)
            .map(|s| s.input_elems())
            .unwrap_or(0)
    }
}
