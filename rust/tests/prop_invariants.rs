//! Property tests over the simulator/shaping invariants, using the
//! in-tree proptest-lite harness (seeded, shrinking).

use trafficshape::config::AcceleratorConfig;
use trafficshape::reuse::{Phase, PhaseClass};
use trafficshape::sim::{max_min_allocate, SimEngine, Workload};
use trafficshape::util::proptest_lite::{check, no_shrink, shrink_vec, Config};
use trafficshape::util::rng::Xoshiro256StarStar;
use trafficshape::util::units::{Bytes, BytesPerS, Flops, FlopsPerS, Seconds};

fn toy_accel(cores: usize) -> AcceleratorConfig {
    let mut a = AcceleratorConfig::knl_7210();
    a.cores = cores;
    a.core_flops_per_s = FlopsPerS(1.0);
    a.mem_bw = BytesPerS(50.0);
    a.conv_efficiency = 1.0;
    a.elementwise_efficiency = 1.0;
    a
}

fn phase(flops: f64, bytes: f64) -> Phase {
    Phase {
        name: String::new(),
        layer_id: 0,
        class: PhaseClass::ComputeDense,
        flops: Flops(flops),
        bytes: Bytes(bytes),
    }
}

/// Random phase program: up to 8 phases of mixed compute/memory weight.
fn gen_program(rng: &mut Xoshiro256StarStar) -> Vec<(f64, f64)> {
    let n = rng.range_u64(1, 8) as usize;
    (0..n)
        .map(|_| {
            let flops = rng.range_f64(0.0, 20.0);
            let bytes = rng.range_f64(0.0, 200.0);
            (flops, bytes)
        })
        .collect()
}

#[test]
fn prop_max_min_allocation_feasible_and_fair() {
    check(
        &Config { cases: 200, seed: 0xA11C, max_shrink_steps: 100 },
        "max-min allocation feasibility",
        |rng| {
            let n = rng.range_u64(1, 12) as usize;
            let peak = rng.range_f64(1.0, 500.0);
            let demands: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.next_f64() < 0.1 {
                        f64::INFINITY
                    } else {
                        rng.range_f64(0.0, 300.0)
                    }
                })
                .collect();
            (peak, demands)
        },
        no_shrink,
        |(peak, demands)| {
            let alloc = max_min_allocate(*peak, demands);
            let total: f64 = alloc.iter().sum();
            if total > peak * (1.0 + 1e-9) {
                return Err(format!("total {total} > peak {peak}"));
            }
            for (a, d) in alloc.iter().zip(demands) {
                if *a > *d + 1e-9 {
                    return Err(format!("alloc {a} > demand {d}"));
                }
                if *a < 0.0 {
                    return Err("negative allocation".into());
                }
            }
            // Work conservation: if any demand unmet, pool is saturated.
            let unmet = alloc.iter().zip(demands).any(|(a, d)| a + 1e-9 < *d);
            if unmet && total < peak - 1e-6 {
                return Err(format!("unmet demand but pool not saturated: {total} < {peak}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_byte_and_flop_conservation() {
    check(
        &Config { cases: 60, seed: 0xBEEF, max_shrink_steps: 200 },
        "simulation conserves bytes and flops",
        gen_program,
        shrink_vec,
        |prog| {
            if prog.is_empty() {
                return Ok(());
            }
            let accel = toy_accel(4);
            let phases: Vec<Phase> = prog.iter().map(|&(f, b)| phase(f, b)).collect();
            let workloads = [
                Workload::new("a", 2, phases.clone(), 2),
                Workload::new("b", 2, phases.clone(), 2).with_start_phase(1),
            ];
            let out = SimEngine::new(&accel)
                .run(&workloads)
                .map_err(|e| e.to_string())?;
            out.validate().map_err(|e| e.to_string())
        },
    );
}

#[test]
fn prop_determinism() {
    check(
        &Config { cases: 30, seed: 0xD00D, max_shrink_steps: 50 },
        "same workload → identical outcome",
        gen_program,
        shrink_vec,
        |prog| {
            if prog.is_empty() {
                return Ok(());
            }
            let accel = toy_accel(2);
            let phases: Vec<Phase> = prog.iter().map(|&(f, b)| phase(f, b)).collect();
            let w = || [Workload::new("a", 1, phases.clone(), 2)];
            let o1 = SimEngine::new(&accel).run(&w()).map_err(|e| e.to_string())?;
            let o2 = SimEngine::new(&accel).run(&w()).map_err(|e| e.to_string())?;
            if (o1.makespan.0 - o2.makespan.0).abs() > 0.0 {
                return Err(format!("makespans differ: {} vs {}", o1.makespan.0, o2.makespan.0));
            }
            if (o1.total_bytes - o2.total_bytes).abs() > 0.0 {
                return Err("byte totals differ".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_makespan_monotone_in_peak_bandwidth() {
    check(
        &Config { cases: 40, seed: 0xCAFE, max_shrink_steps: 100 },
        "more bandwidth never slows the machine",
        gen_program,
        shrink_vec,
        |prog| {
            if prog.is_empty() {
                return Ok(());
            }
            let phases: Vec<Phase> = prog.iter().map(|&(f, b)| phase(f, b)).collect();
            let mut last = f64::INFINITY;
            for bw in [10.0, 30.0, 90.0, 270.0] {
                let mut accel = toy_accel(4);
                accel.mem_bw = BytesPerS(bw);
                let workloads = [
                    Workload::new("a", 2, phases.clone(), 1),
                    Workload::new("b", 2, phases.clone(), 1),
                ];
                let out = SimEngine::new(&accel)
                    .run(&workloads)
                    .map_err(|e| e.to_string())?;
                if out.makespan.0 > last * (1.0 + 1e-9) {
                    return Err(format!(
                        "bw {bw}: makespan {} > previous {last}",
                        out.makespan.0
                    ));
                }
                last = out.makespan.0;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_start_delay_shifts_but_preserves_work() {
    check(
        &Config { cases: 40, seed: 0xF00D, max_shrink_steps: 100 },
        "delay shifts completion, conserves work",
        |rng| {
            let prog = gen_program(rng);
            let delay = rng.range_f64(0.0, 5.0);
            (prog, delay)
        },
        no_shrink,
        |(prog, delay)| {
            if prog.is_empty() {
                return Ok(());
            }
            let accel = toy_accel(2);
            let phases: Vec<Phase> = prog.iter().map(|&(f, b)| phase(f, b)).collect();
            let base = SimEngine::new(&accel)
                .run(&[Workload::new("a", 2, phases.clone(), 1)])
                .map_err(|e| e.to_string())?;
            let delayed = SimEngine::new(&accel)
                .run(&[Workload::new("a", 2, phases.clone(), 1)
                    .with_start_delay(Seconds(*delay))])
                .map_err(|e| e.to_string())?;
            let want = base.makespan.0 + delay;
            if (delayed.makespan.0 - want).abs() > 1e-6 * want.max(1.0) {
                return Err(format!(
                    "delayed makespan {} != base+delay {want}",
                    delayed.makespan.0
                ));
            }
            if (delayed.total_bytes - base.total_bytes).abs() > 1e-9 {
                return Err("bytes changed under delay".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partition_count_preserves_total_flops() {
    // Machine-wide FLOPs are invariant to how cores are partitioned
    // (weight *bytes* grow, compute does not).
    use trafficshape::model::resnet50;
    use trafficshape::shaping::{build_workloads, PartitionPlan, StaggerPolicy};
    let accel = AcceleratorConfig::knl_7210();
    let g = resnet50();
    let flops_at = |n: usize| -> f64 {
        let plan = PartitionPlan::new(&accel, n).unwrap();
        build_workloads(&accel, &g, &plan, 2, StaggerPolicy::UniformPhase)
            .iter()
            .map(|w| w.total_flops())
            .sum()
    };
    let base = flops_at(1);
    for n in [2, 4, 8, 16, 32] {
        let f = flops_at(n);
        assert!(
            (f / base - 1.0).abs() < 1e-9,
            "n={n}: total flops {f} != baseline {base}"
        );
    }
}
