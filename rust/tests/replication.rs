//! Monte-Carlo replication integration: the statistical-methodology
//! contract from the docs — `--replications 1` *is* the classic
//! single-run path, replicated runs quote defensible (nonzero-width)
//! confidence intervals under stochastic arrivals, and every mean/CI
//! column is byte-identical whatever the worker-thread count.

use trafficshape::config::AcceleratorConfig;
use trafficshape::model::tiny_cnn;
use trafficshape::serve::{ArrivalKind, ServeCurve, ServeExperiment, DEFAULT_MEAN_BURST_S};

fn knl() -> AcceleratorConfig {
    AcceleratorConfig::knl_7210()
}

/// A short bursty overload curve on the tiny model: quick to run, with
/// enough stream randomness that different seeds see different tails.
fn curve(replications: usize, threads: usize) -> ServeCurve {
    ServeExperiment::new(&knl(), &tiny_cnn())
        .partitions(vec![1, 2])
        .rates(vec![4000.0])
        .arrival(ArrivalKind::Bursty { burstiness: 4.0, mean_burst_s: DEFAULT_MEAN_BURST_S })
        .duration(0.02)
        .seed(11)
        .trace_samples(64)
        .replications(replications)
        .threads(threads)
        .run()
        .unwrap()
}

#[test]
fn replications_one_reproduces_the_single_run_reports_byte_for_byte() {
    // The classic path: no replications knob touched at all.
    let classic = ServeExperiment::new(&knl(), &tiny_cnn())
        .partitions(vec![1, 2])
        .rates(vec![4000.0])
        .arrival(ArrivalKind::Bursty { burstiness: 4.0, mean_burst_s: DEFAULT_MEAN_BURST_S })
        .duration(0.02)
        .seed(11)
        .trace_samples(64)
        .threads(1)
        .run()
        .unwrap();
    let single = curve(1, 1);
    assert!(!single.is_replicated());
    assert_eq!(single.to_csv().to_string(), classic.to_csv().to_string());
    assert_eq!(single.render(), classic.render());
    assert_eq!(
        single.summary_json().to_string_pretty(),
        classic.summary_json().to_string_pretty()
    );
    // No CI columns leak into the single-run artifact.
    let header = single.to_csv().to_string().lines().next().unwrap().to_string();
    assert!(!header.contains("_ci95"));
    assert!(header.ends_with(",reason"));
}

#[test]
fn bursty_replications_quote_a_nonzero_p99_interval() {
    let rep = curve(5, 1);
    assert_eq!(rep.replications(), Some(5));

    // Every completed point folded all five replications, and the seeded
    // bursty streams disagree enough that the p99 interval has width.
    let stats: Vec<_> = rep.points.iter().filter_map(|p| p.stats.as_ref()).collect();
    assert!(!stats.is_empty(), "completed points must carry folds");
    for s in &stats {
        assert_eq!(s.replications(), 5);
    }
    assert!(
        stats.iter().any(|s| s.p99_ms.ci > 0.0),
        "five bursty seeds must not agree on p99 exactly"
    );

    // The CI columns extend (never reorder) the single-run header.
    let single_header = curve(1, 1).to_csv().to_string().lines().next().unwrap().to_string();
    let csv = rep.to_csv().to_string();
    let header = csv.lines().next().unwrap();
    assert!(header.starts_with(&single_header));
    assert!(header.contains(",p99_ms_mean,p99_ms_ci95,"));

    // The time-binned profile export rides along.
    let profile = rep.profile.as_ref().expect("replicated curves export a profile");
    assert!(!profile.is_empty());
    assert!(profile.to_csv().to_string().starts_with("bin,t_start_s,t_end_s,arrived_mean"));
}

#[test]
fn replicated_reports_are_byte_identical_across_thread_counts() {
    let t1 = curve(3, 1);
    for threads in [2, 4] {
        let tn = curve(3, threads);
        assert_eq!(tn.to_csv().to_string(), t1.to_csv().to_string(), "threads {threads}");
        assert_eq!(tn.render(), t1.render(), "threads {threads}");
        assert_eq!(
            tn.summary_json().to_string_pretty(),
            t1.summary_json().to_string_pretty(),
            "threads {threads}"
        );
        let (pa, pb) = (t1.profile.as_ref().unwrap(), tn.profile.as_ref().unwrap());
        assert_eq!(pa.to_csv().to_string(), pb.to_csv().to_string(), "threads {threads}");
    }
}
