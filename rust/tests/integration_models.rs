//! Cross-module integration: model zoo × traffic model × DRAM model.

use trafficshape::config::AcceleratorConfig;
use trafficshape::model::{self, LayerKind};
use trafficshape::reuse::{model_weight_bytes, PhaseCompiler, TrafficModel};
use trafficshape::sim::DramModel;

#[test]
fn zoo_is_complete_and_valid() {
    for name in ["alexnet", "vgg16", "googlenet", "resnet50", "tiny"] {
        let g = model::by_name(name).unwrap();
        g.validate().unwrap();
        assert!(g.flops_per_image() > 0.0);
        assert!(g.param_elems() > 0);
    }
    assert!(model::by_name("lenet").is_err());
}

#[test]
fn published_parameter_counts() {
    // (model, params in millions, tolerance)
    for (name, want, tol) in [
        ("alexnet", 61.0, 1.0),
        ("vgg16", 138.36, 0.5),
        ("googlenet", 7.0, 0.5),
        ("resnet50", 25.56, 0.6),
    ] {
        let g = model::by_name(name).unwrap();
        let got = g.param_elems() as f64 / 1e6;
        assert!((got - want).abs() < tol, "{name}: {got:.2} M vs {want} M");
    }
}

#[test]
fn every_paper_model_compiles_to_phases_everywhere() {
    let accel = AcceleratorConfig::knl_7210();
    for name in model::PAPER_MODELS {
        let g = model::by_name(name).unwrap();
        for cores in [4, 8, 16, 32, 64] {
            let phases = PhaseCompiler::new(&accel, cores, cores).compile(&g);
            assert_eq!(phases.len(), g.len() - 1, "{name}@{cores}");
            let mut moved = 0usize;
            for p in &phases {
                assert!(p.bytes.0 >= 0.0, "{name}/{}: negative bytes", p.name);
                assert!(p.bytes.0.is_finite() && p.flops.0.is_finite());
                if p.bytes.0 > 0.0 {
                    moved += 1;
                }
            }
            // Fused ReLU/split/dropout phases are traffic-free, but the
            // bulk of the network must move bytes.
            assert!(moved * 2 >= phases.len(), "{name}@{cores}: too few traffic phases");
        }
    }
}

#[test]
fn weight_bytes_anchor_dram_feasibility() {
    // The chain that produces the paper's "VGG up to 8 partitions" rule.
    let accel = AcceleratorConfig::knl_7210();
    let dram = DramModel::new(&accel);
    let vgg = model::vgg16();
    let w = model_weight_bytes(&vgg, accel.elem_bytes);
    // VGG-16 weights ≈ 553 MB → 16 copies ≈ 8.8 GB, over half of MCDRAM.
    assert!(w.0 > 0.5e9);
    assert!(!dram.feasible(&vgg, 16, 64));
    assert!(dram.feasible(&vgg, 8, 64));
}

#[test]
fn split_layers_exist_in_residual_models_only() {
    let has_split = |g: &trafficshape::model::Graph| {
        g.count_kind(|k| matches!(k, LayerKind::Split { .. })) > 0
    };
    assert!(has_split(&model::resnet50()));
    assert!(has_split(&model::googlenet())); // inception fan-out
    assert!(has_split(&model::tiny_cnn()));
    assert!(!has_split(&model::vgg16()));
    assert!(!has_split(&model::alexnet()));
}

#[test]
fn traffic_model_is_deterministic() {
    let accel = AcceleratorConfig::knl_7210();
    let g = model::resnet50();
    let m = TrafficModel::new(&accel, 64);
    let (a, ta) = m.network_traffic(&g, 64);
    let (b, tb) = m.network_traffic(&g, 64);
    assert_eq!(a.len(), b.len());
    assert_eq!(ta.total().0, tb.total().0);
}

#[test]
fn tiny_cnn_matches_python_twin_param_count() {
    // python/tests/test_model.py asserts the same closed-form number.
    let g = model::tiny_cnn();
    let expected = (3 * 3 * 3 * 16 + 16 + 32)
        + 2 * (3 * 3 * 16 * 16 + 16 + 32)
        + (3 * 3 * 16 * 32 + 32 + 64)
        + 2 * (3 * 3 * 32 * 32 + 32 + 64)
        + (32 * 10 + 10);
    // rust counts conv bias + BN(2C); python folds bias into BN shift:
    // python total = rust total − Σ conv biases.
    let conv_biases = 16 + 16 + 16 + 32 + 32 + 32;
    assert_eq!(g.param_elems(), expected);
    let python_twin = 28_698; // from python/tests/test_model.py closed form
    assert_eq!(expected - conv_biases, python_twin);
}
