//! Property tests over the dynamic (serving) engine mode and the serve
//! pipeline, using the in-tree proptest-lite harness: byte conservation,
//! bandwidth feasibility and monotone event/job times under randomized
//! request workloads, plus seed-determinism of the latency percentiles.

use std::sync::Arc;
use trafficshape::config::AcceleratorConfig;
use trafficshape::model::tiny_cnn;
use trafficshape::reuse::{Phase, PhaseClass};
use trafficshape::serve::{AdaptiveConfig, ArrivalProcess, ServeSimulator};
use trafficshape::sim::{DynJob, DynNext, SimEngine, WorkSource};
use trafficshape::util::proptest_lite::{check, no_shrink, shrink_vec, Config};
use trafficshape::util::rng::Xoshiro256StarStar;
use trafficshape::util::units::{Bytes, BytesPerS, Flops, FlopsPerS};

fn toy_accel(cores: usize) -> AcceleratorConfig {
    let mut a = AcceleratorConfig::knl_7210();
    a.cores = cores;
    a.core_flops_per_s = FlopsPerS(1.0);
    a.mem_bw = BytesPerS(50.0);
    a.conv_efficiency = 1.0;
    a.elementwise_efficiency = 1.0;
    a
}

fn phase(flops: f64, bytes: f64) -> Phase {
    Phase {
        name: String::new(),
        layer_id: 0,
        class: PhaseClass::ComputeDense,
        flops: Flops(flops),
        bytes: Bytes(bytes),
    }
}

/// One scripted request stream per partition: (release time, program).
type PartitionScript = Vec<(f64, Vec<(f64, f64)>)>;

/// Pull-based source replaying per-partition scripts in order.
struct ScriptSource {
    scripts: Vec<PartitionScript>,
    cursor: Vec<usize>,
    next_id: u64,
}

impl ScriptSource {
    fn new(scripts: Vec<PartitionScript>) -> Self {
        let cursor = vec![0; scripts.len()];
        Self { scripts, cursor, next_id: 0 }
    }
}

impl WorkSource for ScriptSource {
    fn next(&mut self, partition: usize, now: f64) -> DynNext {
        let k = self.cursor[partition];
        match self.scripts[partition].get(k) {
            None => DynNext::Finished,
            Some((release, prog)) => {
                if *release > now {
                    DynNext::IdleUntil(*release)
                } else {
                    self.cursor[partition] += 1;
                    let id = self.next_id;
                    self.next_id += 1;
                    let phases = prog.iter().map(|&(f, b)| phase(f, b)).collect();
                    DynNext::Job(DynJob { id, phases: Arc::new(phases) })
                }
            }
        }
    }
}

/// Random scripts: 1–3 partitions, each 0–5 jobs of 1–4 phases with
/// mixed compute/memory weight and release times in [0, 2).
fn gen_scripts(rng: &mut Xoshiro256StarStar) -> Vec<PartitionScript> {
    let parts = rng.range_u64(1, 3) as usize;
    (0..parts)
        .map(|_| {
            let jobs = rng.range_u64(0, 5) as usize;
            let mut t = 0.0;
            (0..jobs)
                .map(|_| {
                    t += rng.range_f64(0.0, 1.0);
                    let phases = (0..rng.range_u64(1, 4))
                        .map(|_| (rng.range_f64(0.0, 10.0), rng.range_f64(0.0, 100.0)))
                        .collect();
                    (t, phases)
                })
                .collect()
        })
        .collect()
}

#[test]
fn prop_dynamic_runs_conserve_and_stay_feasible() {
    check(
        &Config { cases: 60, seed: 0x5EED, max_shrink_steps: 100 },
        "dynamic serve runs conserve bytes and respect peak bandwidth",
        gen_scripts,
        shrink_vec,
        |scripts| {
            if scripts.is_empty() {
                return Ok(());
            }
            let accel = toy_accel(4);
            let cores = vec![1usize; scripts.len()];
            let total_jobs: usize = scripts.iter().map(|s| s.len()).sum();
            let mut src = ScriptSource::new(scripts.clone());
            let out = SimEngine::new(&accel)
                .run_dynamic(&cores, &mut src)
                .map_err(|e| e.to_string())?;
            out.validate().map_err(|e| e.to_string())?;
            if out.jobs.len() != total_jobs {
                return Err(format!("{} jobs recorded of {total_jobs}", out.jobs.len()));
            }
            // Bandwidth feasibility + monotone event time, segment by
            // segment.
            let mut prev_end = f64::NEG_INFINITY;
            for (t0, t1, bw) in out.trace.total.segments() {
                if t1 <= t0 {
                    return Err(format!("non-monotone segment [{t0}, {t1})"));
                }
                if t0 < prev_end - 1e-12 {
                    return Err(format!("segment overlaps previous end {prev_end}: {t0}"));
                }
                prev_end = t1;
                if bw > accel.mem_bw.0 * (1.0 + 1e-9) {
                    return Err(format!("bw {bw} exceeds peak in [{t0}, {t1})"));
                }
            }
            // Per-partition job records must be sequential and gated by
            // their release times.
            for (p, script) in scripts.iter().enumerate() {
                let jobs = out.jobs_of(p);
                let mut prev_finish = 0.0f64;
                for (k, job) in jobs.iter().enumerate() {
                    if job.finished_at < job.started_at {
                        return Err(format!("job {} runs backwards", job.id));
                    }
                    if job.started_at + 1e-9 < prev_finish {
                        return Err(format!(
                            "partition {p} job {k} starts before its predecessor ends"
                        ));
                    }
                    if job.started_at + 1e-9 < script[k].0 {
                        return Err(format!("partition {p} job {k} started before release"));
                    }
                    prev_finish = job.finished_at;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_serve_percentiles_are_seed_deterministic() {
    check(
        &Config { cases: 12, seed: 0xD1CE, max_shrink_steps: 0 },
        "serve latency percentiles are a pure function of the seed",
        |rng| {
            let rate = rng.range_f64(500.0, 8000.0);
            let partitions = [1usize, 2, 4][rng.next_below(3) as usize];
            let seed = rng.next_u64();
            (rate, partitions, seed)
        },
        no_shrink,
        |&(rate, partitions, seed)| {
            let accel = AcceleratorConfig::knl_7210();
            let graph = tiny_cnn();
            let run = || {
                ServeSimulator::new(&accel, &graph)
                    .partitions(partitions)
                    .arrival(ArrivalProcess::poisson(rate))
                    .duration(0.02)
                    .seed(seed)
                    .trace_samples(32)
                    .run()
                    .map_err(|e| e.to_string())
            };
            let a = run()?;
            let b = run()?;
            if a.latency != b.latency {
                return Err(format!("latency differs: {:?} vs {:?}", a.latency, b.latency));
            }
            if a.requests != b.requests || a.makespan_s != b.makespan_s {
                return Err("stream or makespan differs across identical runs".into());
            }
            // Ordering sanity on every random configuration.
            let l = &a.latency;
            if l.p50_ms > l.p95_ms || l.p95_ms > l.p99_ms || l.p99_ms > l.max_ms {
                return Err(format!("percentiles out of order: {l:?}"));
            }
            if l.count != a.requests {
                return Err(format!("{} latencies for {} requests", l.count, a.requests));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_serve_drains_every_request() {
    check(
        &Config { cases: 10, seed: 0xFEED, max_shrink_steps: 0 },
        "open-loop serving never drops a request",
        |rng| (rng.range_f64(1000.0, 20_000.0), rng.next_u64()),
        no_shrink,
        |&(rate, seed)| {
            let accel = AcceleratorConfig::knl_7210();
            let out = ServeSimulator::new(&accel, &tiny_cnn())
                .partitions(2)
                .arrival(ArrivalProcess::poisson(rate))
                .duration(0.01)
                .seed(seed)
                .trace_samples(16)
                .run()
                .map_err(|e| e.to_string())?;
            if out.latency.count != out.requests {
                return Err(format!("served {} of {}", out.latency.count, out.requests));
            }
            if out.requests > 0 && out.makespan_s <= 0.0 {
                return Err("served requests but zero makespan".into());
            }
            if out.mean_batch < 1.0 && out.requests > 0 {
                return Err(format!("mean batch {} < 1", out.mean_batch));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_overload_accounting_is_conserved() {
    check(
        &Config { cases: 14, seed: 0xCAFE, max_shrink_steps: 0 },
        "served + dropped = arrived; goodput <= throughput; queue <= cap",
        |rng| {
            let rate = rng.range_f64(2000.0, 50_000.0);
            let cap = rng.range_u64(1, 16) as usize;
            let slo_ms = rng.range_f64(0.5, 50.0);
            let timeout_ms = [0.0, rng.range_f64(0.1, 5.0)][rng.next_below(2) as usize];
            (rate, cap, slo_ms, timeout_ms, rng.next_u64())
        },
        no_shrink,
        |&(rate, cap, slo_ms, timeout_ms, seed)| {
            let accel = AcceleratorConfig::knl_7210();
            let out = ServeSimulator::new(&accel, &tiny_cnn())
                .partitions(2)
                .arrival(ArrivalProcess::poisson(rate))
                .duration(0.01)
                .seed(seed)
                .queue_cap(cap)
                .slo_ms(slo_ms)
                .batch_timeout_ms(timeout_ms)
                .trace_samples(16)
                .run()
                .map_err(|e| e.to_string())?;
            if out.served + out.dropped != out.requests {
                return Err(format!(
                    "{} served + {} dropped != {} arrived",
                    out.served, out.dropped, out.requests
                ));
            }
            if out.latency.count != out.served {
                return Err(format!("{} samples for {} served", out.latency.count, out.served));
            }
            if out.latency.dropped != out.dropped {
                return Err("recorder and controller disagree on drops".into());
            }
            if out.queue_peak > cap {
                return Err(format!("queue peak {} exceeds cap {cap}", out.queue_peak));
            }
            if out.goodput_ips > out.throughput_ips + 1e-9 {
                return Err(format!(
                    "goodput {} exceeds throughput {}",
                    out.goodput_ips, out.throughput_ips
                ));
            }
            if !(0.0..=1.0).contains(&out.drop_rate) {
                return Err(format!("drop rate {} out of range", out.drop_rate));
            }
            if out.latency.slo_hits > out.served {
                return Err("more SLO hits than served requests".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adaptive_reconfigurations_conserve_requests() {
    check(
        &Config { cases: 12, seed: 0xA11, max_shrink_steps: 0 },
        "across online re-partitioning: served + dropped = arrived (per epoch, per run), \
         goodput <= throughput, queue peak <= cap, backlog chains across epochs",
        |rng| {
            let lo = rng.range_f64(1000.0, 5000.0);
            let hi = rng.range_f64(1e5, 2e7);
            let cap = [0usize, rng.range_u64(1, 32) as usize][rng.next_below(2) as usize];
            let slo_ms = [0.0, rng.range_f64(0.5, 50.0)][rng.next_below(2) as usize];
            (lo, hi, cap, slo_ms, rng.next_u64())
        },
        no_shrink,
        |&(lo, hi, cap, slo_ms, seed)| {
            let accel = AcceleratorConfig::knl_7210();
            let out = ServeSimulator::new(&accel, &tiny_cnn())
                .partitions(1)
                .arrival(ArrivalProcess::step_profile(lo, hi, 0.002))
                .duration(0.003)
                .seed(seed)
                .queue_cap(cap)
                .slo_ms(slo_ms)
                .trace_samples(16)
                .adaptive(AdaptiveConfig::new(vec![1, 2, 4]).epoch_s(0.0005))
                .run()
                .map_err(|e| e.to_string())?;
            if out.served + out.dropped != out.requests {
                return Err(format!(
                    "{} served + {} dropped != {} arrived",
                    out.served, out.dropped, out.requests
                ));
            }
            if out.latency.count != out.served || out.latency.dropped != out.dropped {
                return Err("recorder and epoch loop disagree".into());
            }
            if cap > 0 && out.queue_peak > cap {
                return Err(format!("queue peak {} exceeds cap {cap}", out.queue_peak));
            }
            if out.goodput_ips > out.throughput_ips + 1e-9 {
                return Err(format!(
                    "goodput {} exceeds throughput {}",
                    out.goodput_ips, out.throughput_ips
                ));
            }
            if out.epochs.is_empty() {
                return Err("adaptive run recorded no epochs".into());
            }
            let mut prev_out = 0usize;
            let mut arrived = 0usize;
            let (mut served, mut dropped) = (0usize, 0usize);
            for (i, e) in out.epochs.iter().enumerate() {
                if !e.is_conserving() {
                    return Err(format!("epoch {i} leaks requests: {e:?}"));
                }
                if i > 0 && e.carried_in != prev_out {
                    return Err(format!("backlog chain breaks at epoch {i}"));
                }
                if !(0.0..=1.0).contains(&e.utilization) {
                    return Err(format!("utilization {} out of range", e.utilization));
                }
                prev_out = e.carried_out;
                arrived += e.arrived;
                served += e.served;
                dropped += e.dropped;
            }
            if prev_out != 0 {
                return Err("the final epoch left a backlog".into());
            }
            if arrived != out.requests || served != out.served || dropped != out.dropped {
                return Err("epoch totals disagree with the run totals".into());
            }
            // The trajectory is consistent with the event log.
            if out.partition_trajectory().len() != out.reconfigurations() + 1 {
                return Err(format!(
                    "trajectory {:?} vs {} reconfigurations",
                    out.partition_trajectory(),
                    out.reconfigurations()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_unbounded_runs_never_drop() {
    check(
        &Config { cases: 8, seed: 0xB0A7, max_shrink_steps: 0 },
        "without a cap or SLO every arrival is served, whatever the batching policy",
        |rng| {
            let rate = rng.range_f64(1000.0, 20_000.0);
            let timeout_ms = [0.0, rng.range_f64(0.1, 10.0)][rng.next_below(2) as usize];
            (rate, timeout_ms, rng.next_u64())
        },
        no_shrink,
        |&(rate, timeout_ms, seed)| {
            let accel = AcceleratorConfig::knl_7210();
            let out = ServeSimulator::new(&accel, &tiny_cnn())
                .partitions(2)
                .arrival(ArrivalProcess::poisson(rate))
                .duration(0.01)
                .seed(seed)
                .batch_timeout_ms(timeout_ms)
                .trace_samples(16)
                .run()
                .map_err(|e| e.to_string())?;
            if out.dropped != 0 {
                return Err(format!("unbounded run dropped {}", out.dropped));
            }
            if out.served != out.requests {
                return Err(format!("served {} of {}", out.served, out.requests));
            }
            if (out.goodput_ips - out.throughput_ips).abs() > 1e-9 {
                return Err("no SLO: goodput must equal throughput".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_multi_tenant_conservation_holds_per_tenant() {
    use trafficshape::serve::{MultiTenantSimulator, TenantMode, TenantSpec};
    check(
        &Config { cases: 10, seed: 0x7E4A, max_shrink_steps: 0 },
        "per tenant: carried_in + arrived = served + dropped + carried_out every epoch, \
         served + dropped = requests over the run; aggregate = sum of tenants",
        |rng| {
            let k = 1 + rng.next_below(3) as usize;
            let rates: Vec<f64> = (0..k).map(|_| rng.range_f64(500.0, 50_000.0)).collect();
            let shares: Vec<f64> = (0..k).map(|_| rng.range_f64(0.1, 10.0)).collect();
            let caps: Vec<usize> = (0..k)
                .map(|_| if rng.next_below(2) == 0 { 0 } else { rng.range_u64(1, 16) as usize })
                .collect();
            let slos: Vec<f64> = (0..k)
                .map(|_| if rng.next_below(2) == 0 { 0.0 } else { rng.range_f64(0.5, 20.0) })
                .collect();
            let timeshared = rng.next_below(2) == 0;
            let rebalance = rng.next_below(2) == 0;
            (rates, shares, caps, slos, timeshared, rebalance, rng.next_u64())
        },
        no_shrink,
        |(rates, shares, caps, slos, timeshared, rebalance, seed)| {
            let accel = AcceleratorConfig::knl_7210();
            let specs: Vec<TenantSpec> = rates
                .iter()
                .zip(shares)
                .zip(caps)
                .zip(slos)
                .map(|(((&r, &s), &c), &d)| {
                    TenantSpec::new(tiny_cnn(), s, ArrivalProcess::poisson(r))
                        .queue_cap(c)
                        .slo_ms(d)
                })
                .collect();
            let mode = if *timeshared { TenantMode::TimeShared } else { TenantMode::Coscheduled };
            let out = MultiTenantSimulator::new(&accel, specs)
                .duration(0.004)
                .seed(*seed)
                .mode(mode)
                .epoch(0.001)
                .rebalance(*rebalance)
                .trace_samples(16)
                .run()
                .map_err(|e| e.to_string())?;
            let mut served = 0usize;
            let mut dropped = 0usize;
            let mut requests = 0usize;
            for (i, t) in out.tenants.iter().enumerate() {
                let o = &t.outcome;
                if o.served + o.dropped != o.requests {
                    return Err(format!(
                        "tenant {i}: {} served + {} dropped != {} requests",
                        o.served, o.dropped, o.requests
                    ));
                }
                if o.latency.count != o.served {
                    return Err(format!("tenant {i}: latency samples != served"));
                }
                for (j, e) in o.epochs.iter().enumerate() {
                    if !e.is_conserving() {
                        return Err(format!("tenant {i} epoch {j} leaks: {e:?}"));
                    }
                    if j + 1 < o.epochs.len() && e.carried_out != o.epochs[j + 1].carried_in {
                        return Err(format!("tenant {i} epoch {j}: backlog chain breaks"));
                    }
                }
                if let Some(last) = o.epochs.last() {
                    if last.carried_out != 0 {
                        return Err(format!("tenant {i} never drained"));
                    }
                }
                if caps[i] > 0 && o.queue_peak > caps[i] {
                    return Err(format!("tenant {i}: queue peak {} > cap", o.queue_peak));
                }
                served += o.served;
                dropped += o.dropped;
                requests += o.requests;
            }
            let agg = &out.aggregate;
            if (agg.served, agg.dropped, agg.requests) != (served, dropped, requests) {
                return Err("aggregate counters are not the tenant sums".into());
            }
            if agg.goodput_ips > agg.throughput_ips + 1e-9 {
                return Err("aggregate goodput exceeds throughput".into());
            }
            if agg.latency.count != agg.served {
                return Err("aggregate latency samples != served".into());
            }
            Ok(())
        },
    );
}
