//! Sweep-engine integration: parallel execution must be indistinguishable
//! from serial execution, and the aggregated report must reproduce the
//! paper's qualitative claims.

use trafficshape::config::AcceleratorConfig;
use trafficshape::sweep::{ScenarioStatus, SweepGrid, SweepRunner};

fn knl() -> AcceleratorConfig {
    AcceleratorConfig::knl_7210()
}

fn small_grid() -> SweepGrid {
    SweepGrid::new(&knl())
        .models(vec!["resnet50", "googlenet"])
        .partitions(vec![1, 2, 4])
        .bandwidth_scales(vec![1.0, 0.75])
        .steady_batches(3)
        .trace_samples(128)
}

#[test]
fn report_is_byte_identical_across_thread_counts() {
    // The acceptance bar: same seed/grid ⇒ identical aggregated report
    // for 1 vs N worker threads (rendered table, CSV and JSON summary).
    let serial = SweepRunner::new(small_grid()).threads(1).run().unwrap();
    for threads in [2, 4, 8] {
        let parallel = SweepRunner::new(small_grid()).threads(threads).run().unwrap();
        assert_eq!(
            serial.render(),
            parallel.render(),
            "render differs at {threads} threads"
        );
        assert_eq!(
            serial.to_csv().to_string(),
            parallel.to_csv().to_string(),
            "csv differs at {threads} threads"
        );
        assert_eq!(
            serial.summary_json().to_string_pretty(),
            parallel.summary_json().to_string_pretty(),
            "summary differs at {threads} threads"
        );
    }
}

#[test]
fn two_partition_resnet50_beats_synchronous_baseline() {
    // Smoke test for the paper's headline direction: splitting ResNet-50
    // into 2 asynchronous partitions must beat the sync baseline.
    let grid = SweepGrid::new(&knl())
        .models(vec!["resnet50"])
        .partitions(vec![1, 2])
        .bandwidth_scales(vec![1.0])
        .steady_batches(4)
        .trace_samples(128);
    let report = SweepRunner::new(grid).run().unwrap();
    assert_eq!(report.outcomes.len(), 2);
    let baseline = report.outcomes[0].metrics().unwrap();
    let shaped = report.outcomes[1].metrics().unwrap();
    assert!((baseline.relative_performance - 1.0).abs() < 1e-12);
    assert!(
        shaped.relative_performance > 1.0,
        "2-partition ResNet-50 must beat sync: {}",
        shaped.relative_performance
    );
    assert!(shaped.std_reduction > 0.0, "σ must shrink");
    assert!(
        shaped.smoothness_cov < baseline.smoothness_cov,
        "shaped cov {} must be smoother than sync cov {}",
        shaped.smoothness_cov,
        baseline.smoothness_cov
    );
    // And the ranked report puts the shaped point first.
    assert_eq!(report.best().unwrap().scenario.partitions, 2);
}

#[test]
fn dram_infeasible_points_are_reported_not_fatal() {
    // Paper §4: VGG-16 at 16 partitions exceeds MCDRAM.
    let grid = SweepGrid::new(&knl())
        .models(vec!["vgg16"])
        .partitions(vec![8, 16])
        .bandwidth_scales(vec![1.0])
        .steady_batches(2)
        .trace_samples(64);
    let report = SweepRunner::new(grid).run().unwrap();
    assert!(matches!(report.outcomes[0].status, ScenarioStatus::Completed(_)));
    match &report.outcomes[1].status {
        ScenarioStatus::Infeasible(why) => assert!(why.contains("vgg16"), "{why}"),
        other => panic!("vgg16@16 should be DRAM-infeasible, got {other:?}"),
    }
    assert_eq!(report.completed_count(), 1);
    assert_eq!(report.infeasible_count(), 1);
    // Infeasible rows render as DRAM and export as dram_infeasible.
    assert!(report.render().contains("DRAM"));
    assert!(report.to_csv().to_string().contains("dram_infeasible"));
}

#[test]
fn cap_and_slo_axes_chart_the_overload_surface() {
    // A cap × SLO sub-grid over an overloaded serve rate: every serve
    // row must appear once per (cap, SLO) pair, each against its own
    // matching 1-partition baseline, and the bounded+SLO points must
    // shed load while the unbounded point drains everything.
    let grid = SweepGrid::new(&knl())
        .models(vec!["tiny"])
        .partitions(vec![1, 2])
        .bandwidth_scales(vec![1.0])
        .arrival_rates(vec![2e7])
        .serve_queue_caps(vec![0, 8])
        .serve_slo_ms_axis(vec![0.0, 5.0])
        .serve_duration(5e-4)
        .serve_seed(9)
        .steady_batches(2)
        .trace_samples(16);
    assert_eq!(grid.len(), 8); // 2 caps × 2 SLOs × 2 partition counts
    let report = SweepRunner::new(grid).threads(2).run().unwrap();
    assert_eq!(report.outcomes.len(), 8);
    assert_eq!(report.completed_count(), 8);
    assert_eq!(report.serve_count(), 8);
    let at = |cap: usize, slo: f64, n: usize| {
        report
            .outcomes
            .iter()
            .find(|o| {
                let s = &o.scenario;
                s.queue_cap == cap && s.slo_ms == slo && s.partitions == n
            })
            .and_then(|o| o.metrics())
            .copied()
            .unwrap()
    };
    // Unbounded, no SLO: nothing dropped.
    let open = at(0, 0.0, 2);
    assert_eq!(open.drop_rate, Some(0.0));
    // Bounded + SLO at 2e7 req/s: must shed.
    let tight = at(8, 5.0, 2);
    assert!(tight.drop_rate.unwrap() > 0.0, "overload against cap 8 must drop");
    assert!(tight.goodput_ips.unwrap() <= tight.throughput_ips + 1e-9);
    // Every n = 1 row is its own baseline (per cap × SLO pair).
    for &(cap, slo) in &[(0usize, 0.0), (0, 5.0), (8, 0.0), (8, 5.0)] {
        let base = at(cap, slo, 1);
        assert!(
            (base.relative_performance - 1.0).abs() < 1e-12,
            "cap {cap}/slo {slo} baseline row should be its own baseline"
        );
    }
    // The overload knobs flow into the CSV columns.
    let csv = report.to_csv().to_string();
    assert!(csv.starts_with("id,model,partitions,bandwidth_scale,stagger,arrival_rate,queue_cap"));
    assert!(csv.contains(",8,5,"), "cap/slo values must be exported");
}

#[test]
fn ranked_order_is_descending_in_relative_performance() {
    let report = SweepRunner::new(small_grid()).run().unwrap();
    let ranked = report.ranked();
    let gains: Vec<f64> = ranked
        .iter()
        .filter_map(|o| o.metrics().map(|m| m.relative_performance))
        .collect();
    assert!(!gains.is_empty());
    for w in gains.windows(2) {
        assert!(w[0] >= w[1], "ranking not descending: {w:?}");
    }
    // Every grid point appears exactly once in the ranking.
    assert_eq!(ranked.len(), report.outcomes.len());
}

#[test]
fn bandwidth_scales_sweep_distinct_configs() {
    // The bandwidth axis must actually change the simulated machine:
    // the same (model, n) point at 0.75x bandwidth has a different
    // baseline mean-BW level, and partitioning still pays at both points
    // (ResNet-50 is bandwidth-bound either way).
    let grid = SweepGrid::new(&knl())
        .models(vec!["resnet50"])
        .partitions(vec![1, 4])
        .bandwidth_scales(vec![1.0, 0.75])
        .steady_batches(3)
        .trace_samples(128);
    let report = SweepRunner::new(grid).run().unwrap();
    assert_eq!(report.outcomes.len(), 4);
    let at = |n: usize, scale: f64| {
        report
            .outcomes
            .iter()
            .find(|o| o.scenario.partitions == n && o.scenario.bandwidth_scale == scale)
            .and_then(|o| o.metrics())
            .copied()
            .unwrap()
    };
    let full = at(4, 1.0);
    let starved = at(4, 0.75);
    assert!(full.relative_performance > 1.0, "full-bw gain {}", full.relative_performance);
    assert!(
        starved.relative_performance > 1.0,
        "starved-bw gain {}",
        starved.relative_performance
    );
    // The two bandwidth points are genuinely different machines.
    let base_full = at(1, 1.0);
    let base_starved = at(1, 0.75);
    assert!(
        base_starved.makespan_s > base_full.makespan_s,
        "less bandwidth must lengthen the sync baseline: {} vs {}",
        base_starved.makespan_s,
        base_full.makespan_s
    );
}
