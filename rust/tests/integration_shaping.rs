//! Shaping-layer integration: the paper's evaluation shape end-to-end.

use trafficshape::config::AcceleratorConfig;
use trafficshape::model::{googlenet, resnet50, vgg16};
use trafficshape::shaping::{PartitionExperiment, PartitionPlan, StaggerPolicy, TradeoffModel};

fn knl() -> AcceleratorConfig {
    AcceleratorConfig::knl_7210()
}

#[test]
#[allow(clippy::disallowed_types)] // test-local scratch; iteration order unused
fn headline_gains_in_plausible_bands() {
    // Paper best gains: VGG +3.9%, GoogLeNet +11.1%, ResNet-50 +8.0%.
    // The simulator substitute must land the same ordering with gains in
    // a generous band around the paper's numbers.
    let cases = [
        ("vgg16", vgg16(), vec![2usize, 4, 8], 0.5, 12.0),
        ("googlenet", googlenet(), vec![2, 4, 8, 16], 2.0, 30.0),
        ("resnet50", resnet50(), vec![2, 4, 8, 16], 1.0, 25.0),
    ];
    let mut best = std::collections::HashMap::new();
    for (name, graph, parts, lo_pct, hi_pct) in cases {
        let mut best_gain = 0.0f64;
        for n in parts {
            let r = PartitionExperiment::new(&knl(), &graph)
                .partitions(n)
                .steady_batches(5)
                .run()
                .unwrap();
            best_gain = best_gain.max((r.relative_performance - 1.0) * 100.0);
        }
        assert!(
            (lo_pct..hi_pct).contains(&best_gain),
            "{name}: best gain {best_gain:.1}% outside [{lo_pct}, {hi_pct}]%"
        );
        best.insert(name, best_gain);
    }
    assert!(best["googlenet"] > best["vgg16"]);
    assert!(best["resnet50"] > best["vgg16"]);
}

#[test]
fn sigma_reduction_monotone_in_partitions_for_resnet() {
    // Fig 5: σ(BW) falls as n grows.
    let g = resnet50();
    let mut last = 0.0;
    for n in [2, 4, 8, 16] {
        let r = PartitionExperiment::new(&knl(), &g)
            .partitions(n)
            .steady_batches(4)
            .run()
            .unwrap();
        assert!(
            r.std_reduction >= last - 0.05,
            "σ reduction regressed at n={n}: {} after {last}",
            r.std_reduction
        );
        last = last.max(r.std_reduction);
    }
    assert!(last > 0.2, "16 partitions should cut σ by >20%: {last}");
}

#[test]
fn paper_feasibility_matrix() {
    let accel = knl();
    // (model, n, feasible?)
    let cases = [
        ("vgg16", 8usize, true),
        ("vgg16", 16, false),
        ("googlenet", 16, true),
        ("resnet50", 16, true),
    ];
    for (name, n, want) in cases {
        let g = trafficshape::model::by_name(name).unwrap();
        let plan = PartitionPlan::new(&accel, n).unwrap();
        assert_eq!(
            plan.check_capacity(&accel, &g).is_ok(),
            want,
            "{name}@{n}"
        );
    }
}

#[test]
fn analytic_bounds_bracket_simulated_gain() {
    // TradeoffModel.best_case_gain is an upper bound on the simulated
    // relative performance.
    let accel = knl();
    let g = resnet50();
    let tm = TradeoffModel::new(&accel);
    for n in [2usize, 4, 8] {
        let bound = tm.bounds(&g, n).best_case_gain;
        let sim = PartitionExperiment::new(&accel, &g)
            .partitions(n)
            .steady_batches(4)
            .run()
            .unwrap()
            .relative_performance;
        assert!(
            sim <= bound * 1.02,
            "n={n}: simulated {sim:.3} exceeds analytic bound {bound:.3}"
        );
    }
}

#[test]
fn random_delay_stagger_also_shapes() {
    let g = resnet50();
    let r = PartitionExperiment::new(&knl(), &g)
        .partitions(4)
        .steady_batches(5)
        .stagger(StaggerPolicy::RandomDelay { seed: 7 })
        .run()
        .unwrap();
    assert!(r.std_reduction > 0.0);
    // RandomDelay pays its startup idle inside the measured window (up
    // to one batch of skew over 5 batches), so allow that bias; the
    // steady-state shaping must still keep throughput near baseline.
    assert!(
        r.relative_performance > 0.90,
        "relative perf {}",
        r.relative_performance
    );
}

#[test]
fn unlimited_bandwidth_removes_the_effect() {
    // Fig 3(a): with unlimited BW the sync schedule is already optimal —
    // partitioning can only add weight traffic, so the gain vanishes
    // (relative perf ≤ ~1).
    let accel = AcceleratorConfig::knl_unlimited_bw();
    let g = resnet50();
    let r = PartitionExperiment::new(&accel, &g)
        .partitions(4)
        .steady_batches(4)
        .run()
        .unwrap();
    assert!(
        r.relative_performance <= 1.005,
        "no BW bottleneck → no shaping win, got {:.4}",
        r.relative_performance
    );
}
