//! Runtime + coordinator integration over the REAL artifacts.
//!
//! These tests need `make artifacts` to have run; they are skipped (with
//! a loud message) if the artifact directory is missing so `cargo test`
//! stays usable in a fresh checkout.

use trafficshape::coordinator::{Coordinator, CoordinatorConfig};
use trafficshape::runtime::{find_artifact_dir, Manifest, RuntimeClient};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = find_artifact_dir();
    if dir.is_none() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
    }
    dir
}

#[test]
fn manifest_loads_and_validates() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.model, "tiny_cnn");
    // Stage order is the contract between aot.py and the rust twin.
    assert_eq!(m.stage_order, trafficshape::model::TINY_STAGES.to_vec());
    // Every analytic layer maps into one of the artifact stages.
    let g = trafficshape::model::tiny_cnn();
    for layer in g.layers().iter().skip(1) {
        assert!(
            trafficshape::model::tiny_stage_of(&layer.name).is_some(),
            "layer {} has no stage",
            layer.name
        );
    }
    assert!(m.batches.contains(&1) && m.batches.contains(&8));
    assert_eq!(m.stages.len(), 10);
    // Param accounting matches the rust tiny_cnn twin (minus conv biases
    // which python folds into the BN shift).
    let per_stage: usize = m.stages.iter().filter(|s| s.batch == 1).map(|s| s.param_elems).sum();
    assert_eq!(per_stage, m.param_count);
}

#[test]
fn every_stage_passes_numeric_self_check() {
    // THE composition proof: Pallas kernel → JAX stage → HLO text →
    // PJRT compile → execute reproduces jax's own numbers.
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    for &batch in &[1usize, 8] {
        let rt = RuntimeClient::new(&m, batch).unwrap();
        rt.self_check_all().unwrap();
    }
}

#[test]
fn full_pipeline_forward_produces_logits() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = RuntimeClient::new(&m, 1).unwrap();
    let input: Vec<f32> = (0..3 * 32 * 32).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let logits = rt.forward(1, &input).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    // Deterministic.
    let logits2 = rt.forward(1, &input).unwrap();
    assert_eq!(logits, logits2);
}

#[test]
fn coordinator_runs_and_balances() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = CoordinatorConfig::new(dir);
    cfg.partitions = 2;
    cfg.total_batches = 4;
    cfg.micro_batch = 8;
    cfg.self_check = false;
    let report = Coordinator::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.images, 32);
    assert_eq!(report.jobs_per_worker, vec![2, 2]);
    assert!(report.throughput_ips > 0.0);
    assert!(report.total_traffic_bytes > 0.0);
    assert!(report.bw.mean >= 0.0);
}

#[test]
fn coordinator_checksum_invariant_across_partitions() {
    // Same seed → same images → identical total logits, independent of
    // how work is partitioned.
    let Some(dir) = artifacts() else { return };
    let mut sums = Vec::new();
    for parts in [1usize, 2] {
        let mut cfg = CoordinatorConfig::new(dir.clone());
        cfg.partitions = parts;
        cfg.total_batches = 4;
        cfg.micro_batch = 8;
        cfg.self_check = false;
        cfg.seed = 7;
        let r = Coordinator::new(cfg).unwrap().run().unwrap();
        sums.push(r.logits_checksum);
    }
    let delta = (sums[0] - sums[1]).abs();
    assert!(
        delta < 1e-6 * sums[0].abs().max(1.0),
        "checksums differ: {sums:?}"
    );
}

#[test]
fn coordinator_rejects_bad_config() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = CoordinatorConfig::new(dir.clone());
    cfg.micro_batch = 3; // not an AOT'd batch size
    assert!(Coordinator::new(cfg).is_err());
    let mut cfg = CoordinatorConfig::new(dir);
    cfg.partitions = 0;
    assert!(Coordinator::new(cfg).is_err());
}
