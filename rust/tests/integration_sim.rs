//! Simulator integration: analytically solvable workloads end-to-end.

use trafficshape::config::AcceleratorConfig;
use trafficshape::model::resnet50;
use trafficshape::reuse::{Phase, PhaseClass, PhaseCompiler};
use trafficshape::sim::{SimEngine, Workload};
use trafficshape::util::units::{Bytes, Flops, FlopsPerS, BytesPerS, Seconds};

fn toy_accel(cores: usize, flops_per_core: f64, bw: f64) -> AcceleratorConfig {
    let mut a = AcceleratorConfig::knl_7210();
    a.cores = cores;
    a.core_flops_per_s = FlopsPerS(flops_per_core);
    a.mem_bw = BytesPerS(bw);
    a.conv_efficiency = 1.0;
    a.elementwise_efficiency = 1.0;
    a
}

fn phase(flops: f64, bytes: f64) -> Phase {
    Phase {
        name: format!("f{flops}b{bytes}"),
        layer_id: 0,
        class: PhaseClass::ComputeDense,
        flops: Flops(flops),
        bytes: Bytes(bytes),
    }
}

#[test]
fn closed_form_two_partition_schedule() {
    // Machine: 2 cores × 1 FLOP/s, bandwidth 10 B/s.
    // Program: A(2 flops, 0 B) then B(1 flop, 15 B) per partition.
    // Partition on 1 core: A takes 2 s; B: tc = 1 s, wants 15 B/s.
    //
    // Lockstep: both run A [0,2), then both B demand 15 → alloc 5 each →
    //   B takes 3 s → makespan 5.
    // Anti-phase (p2 starts at B): p2's B alone gets 10 B/s → 1.5 s;
    //   overlap windows make both finish strictly earlier than 5.
    let accel = toy_accel(2, 1.0, 10.0);
    let prog = vec![phase(2.0, 0.0), phase(1.0, 15.0)];
    let engine = SimEngine::new(&accel);

    let lock = engine
        .run(&[
            Workload::new("a", 1, prog.clone(), 1),
            Workload::new("b", 1, prog.clone(), 1),
        ])
        .unwrap();
    assert!((lock.makespan.0 - 5.0).abs() < 1e-9, "{}", lock.makespan.0);

    let anti = engine
        .run(&[
            Workload::new("a", 1, prog.clone(), 1),
            Workload::new("b", 1, prog.clone(), 1).with_start_phase(1),
        ])
        .unwrap();
    assert!(anti.makespan.0 < 5.0 - 1e-9, "{}", anti.makespan.0);
    anti.validate().unwrap();
}

#[test]
fn makespan_monotone_in_bandwidth() {
    // More bandwidth never hurts.
    let g = resnet50();
    let mut last = f64::INFINITY;
    for bw in [100e9, 200e9, 400e9, 800e9] {
        let mut accel = AcceleratorConfig::knl_7210();
        accel.mem_bw = BytesPerS(bw);
        let phases = PhaseCompiler::synchronous(&accel).compile(&g);
        let w = Workload::new("sync", accel.cores, phases, 2);
        let out = SimEngine::new(&accel).run(&[w]).unwrap();
        assert!(
            out.makespan.0 <= last + 1e-9,
            "bw {bw}: makespan {} > previous {last}",
            out.makespan.0
        );
        last = out.makespan.0;
    }
}

#[test]
fn unlimited_bandwidth_hits_compute_roofline() {
    let accel = AcceleratorConfig::knl_unlimited_bw();
    let g = resnet50();
    let compiler = PhaseCompiler::synchronous(&accel);
    let phases = compiler.compile(&g);
    let compute_time: f64 = phases
        .iter()
        .map(|p| p.compute_time(&accel, accel.cores).0)
        .sum();
    let w = Workload::new("sync", accel.cores, phases, 1);
    let out = SimEngine::new(&accel).run(&[w]).unwrap();
    assert!(
        (out.makespan.0 - compute_time).abs() < 1e-6 * compute_time,
        "{} vs {}",
        out.makespan.0,
        compute_time
    );
}

#[test]
fn start_delays_serialize_execution() {
    // Two partitions with delays long enough to never overlap behave
    // like solo runs.
    let accel = toy_accel(2, 1.0, 10.0);
    let prog = vec![phase(1.0, 5.0)]; // solo: 1 s (demand 5 < 10)
    let out = SimEngine::new(&accel)
        .run(&[
            Workload::new("a", 1, prog.clone(), 1),
            Workload::new("b", 1, prog.clone(), 1).with_start_delay(Seconds(10.0)),
        ])
        .unwrap();
    assert!((out.finish_times[0].0 - 1.0).abs() < 1e-9);
    assert!((out.finish_times[1].0 - 11.0).abs() < 1e-9);
}

#[test]
fn resnet_sync_run_satisfies_all_invariants() {
    let accel = AcceleratorConfig::knl_7210();
    let g = resnet50();
    let phases = PhaseCompiler::synchronous(&accel).compile(&g);
    let declared_bytes: f64 = phases.iter().map(|p| p.bytes.0).sum::<f64>() * 3.0;
    let w = Workload::new("sync", accel.cores, phases, 3);
    let out = SimEngine::new(&accel).run(&[w]).unwrap();
    out.validate().unwrap();
    assert!((out.total_bytes - declared_bytes).abs() < 1e-6 * declared_bytes);
    // Achieved FLOPS must be below peak.
    assert!(out.achieved_flops() < accel.peak_flops().0);
    // Average bandwidth below peak.
    assert!(out.avg_bandwidth() < accel.mem_bw.0);
}

#[test]
fn heterogeneous_partitions_are_legal() {
    // Partitions of different core counts (not used by the paper but the
    // engine must not assume symmetry).
    let accel = toy_accel(8, 1.0, 100.0);
    let out = SimEngine::new(&accel)
        .run(&[
            Workload::new("big", 6, vec![phase(6.0, 10.0)], 2),
            Workload::new("small", 2, vec![phase(2.0, 10.0)], 2),
        ])
        .unwrap();
    out.validate().unwrap();
    assert!(out.makespan.0 > 0.0);
}
