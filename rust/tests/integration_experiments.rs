//! Experiment-driver integration: every figure/table regenerates and
//! matches the paper's qualitative claims (fast configurations).

use trafficshape::config::ExperimentConfig;
use trafficshape::experiments::{
    list_experiments, run_by_id, run_fig2, run_fig5, run_table1,
};

fn fast() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.steady_batches = 3;
    cfg.trace_samples = 128;
    cfg
}

#[test]
fn all_registered_experiments_run_and_write() {
    let cfg = fast();
    let dir = std::env::temp_dir().join("ts_exp_integration");
    std::fs::remove_dir_all(&dir).ok();
    for (id, _) in list_experiments() {
        let out = run_by_id(id, &cfg).unwrap();
        out.write_to(&dir).unwrap();
        assert!(dir.join(id).join("summary.json").exists(), "{id}");
        // Summary must parse back.
        let text = std::fs::read_to_string(dir.join(id).join("summary.json")).unwrap();
        trafficshape::util::json::Json::parse(&text).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig2_and_fig5_tell_the_same_story() {
    // The models with the smallest weight share gain the most from
    // partitioning — the paper's causal chain from Fig 2 to Fig 5.
    let cfg = fast();
    let f2 = run_fig2(&cfg).unwrap();
    let f5 = run_fig5(&cfg).unwrap();
    let ratio = |m: &str| f2.rows.iter().find(|(n, _, _)| n == m).unwrap().2;
    let gain = |m: &str| f5.best_gain(m).unwrap();
    // vgg has the biggest weight share of the three and the smallest gain.
    assert!(ratio("vgg16") > ratio("googlenet"));
    assert!(gain("vgg16") < gain("googlenet"));
    assert!(ratio("vgg16") > ratio("resnet50"));
    assert!(gain("vgg16") < gain("resnet50"));
}

#[test]
fn table1_reports_all_six_rows_with_both_columns() {
    let t = run_table1(&fast()).unwrap();
    assert_eq!(t.rows.len(), 6);
    for row in &t.rows {
        assert!(row.bw_gbps > 0.0);
        assert!(row.tflops > 0.0);
        assert!(row.paper_bw_gbps > 0.0);
    }
    let csv = t.to_csv().to_string();
    assert_eq!(csv.lines().count(), 7); // header + 6 rows
}

#[test]
fn experiment_outputs_are_reproducible() {
    // Same config → byte-identical CSV (determinism guarantee recorded
    // in every result file).
    let cfg = fast();
    let a = run_by_id("fig4", &cfg).unwrap();
    let b = run_by_id("fig4", &cfg).unwrap();
    assert_eq!(a.csv[0].1.to_string(), b.csv[0].1.to_string());
    assert_eq!(
        a.summary.to_string_pretty(),
        b.summary.to_string_pretty()
    );
}
