//! Multi-tenant serving integration: the headline claim — balanced
//! co-scheduled tenants beat time-sharing on aggregate tail latency at
//! identical offered load — plus per-tenant conservation, seed
//! determinism and the byte-identical-reports bar for tenant rows.

use trafficshape::config::AcceleratorConfig;
use trafficshape::model::{resnet50, tiny_cnn, vgg16};
use trafficshape::serve::{
    ArrivalProcess, MultiTenantSimulator, ServeExperiment, TenantMode, TenantSpec,
};

fn knl() -> AcceleratorConfig {
    AcceleratorConfig::knl_7210()
}

fn balanced_pair(rate: f64) -> Vec<TenantSpec> {
    vec![
        TenantSpec::new(tiny_cnn(), 1.0, ArrivalProcess::poisson(rate)),
        TenantSpec::new(tiny_cnn(), 1.0, ArrivalProcess::poisson(rate)),
    ]
}

/// ResNet-50 + VGG-16 with FLOP-proportional core shares — the
/// imbalanced-work pair whose equal-split straggle the offline mixed
/// experiment documented; proportional shares are the fix.
fn heterogeneous_pair() -> Vec<TenantSpec> {
    let vgg = vgg16();
    let res = resnet50();
    vec![
        TenantSpec::new(vgg.clone(), vgg.flops_per_image(), ArrivalProcess::poisson(60.0)),
        TenantSpec::new(res.clone(), res.flops_per_image(), ArrivalProcess::poisson(60.0)),
    ]
}

#[test]
fn balanced_cosched_beats_time_sharing_on_aggregate_p99() {
    // The headline: at identical offered load (same tenants, same seeded
    // streams), spatial sharing serves every request on its own slice
    // immediately, while temporal sharing makes a request arriving in a
    // foreign quantum wait out the turn — so co-scheduling must win the
    // aggregate tail outright.
    let run = |mode: TenantMode| {
        MultiTenantSimulator::new(&knl(), balanced_pair(2000.0))
            .duration(0.02)
            .seed(7)
            .mode(mode)
            .epoch(0.002)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let co = run(TenantMode::Coscheduled);
    let ts = run(TenantMode::TimeShared);

    // Identical offered load: the same seeded streams feed both modes.
    assert_eq!(co.aggregate.requests, ts.aggregate.requests);
    assert!(co.aggregate.requests > 40, "want a real stream, got {}", co.aggregate.requests);
    for out in [&co, &ts] {
        assert_eq!(out.aggregate.served, out.aggregate.requests, "unbounded queues drain");
        assert_eq!(out.aggregate.dropped, 0);
        for t in &out.tenants {
            assert_eq!(t.outcome.served + t.outcome.dropped, t.outcome.requests);
            for e in &t.outcome.epochs {
                assert!(e.is_conserving(), "{e:?}");
            }
        }
    }
    assert!(
        co.aggregate.latency.p99_ms < ts.aggregate.latency.p99_ms,
        "co-scheduled aggregate p99 {:.2} ms must beat time-shared {:.2} ms",
        co.aggregate.latency.p99_ms,
        ts.aggregate.latency.p99_ms
    );
    // Goodput == throughput here (no SLO), and neither mode loses work,
    // so the latency win is the whole story at this load.
    assert!(co.aggregate.goodput_ips > 0.0);
}

#[test]
fn heterogeneous_pair_conserves_and_is_seed_deterministic() {
    let run = |seed: u64| {
        MultiTenantSimulator::new(&knl(), heterogeneous_pair())
            .duration(0.2)
            .seed(seed)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let a = run(11);
    let b = run(11);
    assert_eq!(a.aggregate.requests, b.aggregate.requests);
    assert_eq!(a.aggregate.latency, b.aggregate.latency);
    assert_eq!(a.aggregate.makespan_s, b.aggregate.makespan_s);
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.outcome.latency, y.outcome.latency);
        assert_eq!(x.cores, y.cores);
    }
    let c = run(12);
    assert!(
        a.aggregate.requests != c.aggregate.requests || a.aggregate.latency != c.aggregate.latency,
        "seed must matter"
    );
    // Proportional shares: the FLOP-heavy VGG tenant gets the bigger
    // slice, and both tenants' streams are fully accounted for.
    assert!(a.tenants[0].cores > a.tenants[1].cores, "VGG must out-size ResNet");
    assert_eq!(a.tenants[0].cores + a.tenants[1].cores, 64);
    assert!(a.aggregate.requests > 5, "want a real stream, got {}", a.aggregate.requests);
    for t in &a.tenants {
        assert_eq!(t.outcome.served + t.outcome.dropped, t.outcome.requests);
        if t.outcome.served > 0 {
            assert!(t.outcome.latency.p99_ms > 0.0, "tenant {} lost its samples", t.tag);
        }
    }
}

#[test]
fn tenant_reports_are_byte_identical_across_thread_counts() {
    // The determinism bar extends to multi-tenant reports: the seeded
    // ResNet-50 + VGG-16 pair must render byte-identical tables, CSV and
    // JSON for --threads 1 and N, with per-tenant and aggregate rows in
    // both sharing modes.
    let run = |threads: usize| {
        ServeExperiment::new(&knl(), &resnet50())
            .tenants(heterogeneous_pair())
            .duration(0.2)
            .seed(42)
            .trace_samples(64)
            .tenant_epoch_ms(10.0)
            .threads(threads)
            .run()
            .unwrap()
    };
    let serial = run(1);
    for threads in [2, 4] {
        let parallel = run(threads);
        assert_eq!(serial.render(), parallel.render(), "render differs at {threads} threads");
        assert_eq!(
            serial.to_csv().to_string(),
            parallel.to_csv().to_string(),
            "csv differs at {threads} threads"
        );
        assert_eq!(
            serial.summary_json().to_string_pretty(),
            parallel.summary_json().to_string_pretty(),
            "summary differs at {threads} threads"
        );
    }
    // The report carries per-tenant and aggregate rows for both modes,
    // with the latency/goodput columns populated.
    assert_eq!(serial.points.len(), 6, "2 modes x (aggregate + 2 tenants)");
    assert_eq!(serial.model, "vgg16+resnet50");
    let csv = serial.to_csv().to_string();
    assert!(csv.contains(",tenant,tenant_model,tenant_cores,"));
    assert!(csv.contains(",cosched,ok,"));
    assert!(csv.contains(",timeshared,ok,"));
    assert!(csv.contains(",aggregate,mixed,"));
    assert!(csv.contains(",t0,vgg16,"));
    assert!(csv.contains(",t1,resnet50,"));
    let co = serial.tenant_aggregate(TenantMode::Coscheduled).unwrap();
    let ts = serial.tenant_aggregate(TenantMode::TimeShared).unwrap();
    assert_eq!(co.requests, ts.requests, "identical offered load across modes");
    assert!(co.latency.p50_ms > 0.0 && co.latency.p50_ms <= co.latency.p99_ms);
    for (row, o) in serial.tenant_rows(TenantMode::Coscheduled) {
        assert!(!row.is_aggregate());
        assert_eq!(o.served + o.dropped, o.requests, "{} conservation", row.tag);
    }
}
