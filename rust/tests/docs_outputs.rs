//! docs/OUTPUTS.md is a contract, not prose: every column table in it is
//! compared here against the header the code actually emits, both via
//! the `csv_columns` helpers and via real runs of each front-end. Any
//! drift — a column added in code but not documented, or vice versa —
//! fails this test (and CI's docs job).

use trafficshape::cluster::{ClusterConfig, ClusterOutcome, ClusterSimulator, MachineConfig};
use trafficshape::config::AcceleratorConfig;
use trafficshape::model::tiny_cnn;
use trafficshape::serve::{ServeCurve, ServeExperiment};
use trafficshape::sweep::{ReplicationProfile, SweepGrid, SweepReport, SweepRunner};

const DOC: &str = include_str!("../../docs/OUTPUTS.md");

/// The column names documented for one `### <artifact>` section: the
/// first backticked token of each table row.
fn documented_columns(section: &str) -> Vec<String> {
    let marker = format!("### {section}");
    let start = DOC.find(&marker).unwrap_or_else(|| panic!("OUTPUTS.md has no section {section}"));
    let body = &DOC[start + marker.len()..];
    let body = &body[..body.find("\n### ").unwrap_or(body.len())];
    body.lines()
        .filter(|l| l.starts_with("| `"))
        .map(|l| l.split('`').nth(1).expect("backticked column name").to_string())
        .collect()
}

fn assert_columns(section: &str, emitted: &[&str]) {
    assert_eq!(
        documented_columns(section),
        emitted,
        "docs/OUTPUTS.md section {section} disagrees with the emitted header — \
         update the table and the code together"
    );
}

#[test]
fn documented_csv_columns_match_the_helpers() {
    assert_columns("serve_curve.csv", &ServeCurve::csv_columns(true));
    assert_columns("serve_profile.csv", &ReplicationProfile::csv_columns());
    assert_columns("sweep_grid.csv", &SweepReport::csv_columns(true));
    assert_columns("cluster_machines.csv", &ClusterOutcome::csv_columns(true));
}

#[test]
fn documented_columns_match_actually_emitted_headers() {
    let accel = AcceleratorConfig::knl_7210();
    let graph = tiny_cnn();

    // serve: a tiny replicated curve, so the full (base + CI) header is
    // what lands in the artifact.
    let curve = ServeExperiment::new(&accel, &graph)
        .partitions(vec![1])
        .rates(vec![2000.0])
        .duration(0.01)
        .seed(3)
        .trace_samples(32)
        .replications(2)
        .run()
        .unwrap();
    let csv = curve.to_csv().to_string();
    assert_eq!(csv.lines().next().unwrap(), documented_columns("serve_curve.csv").join(","));
    let profile = curve.profile.as_ref().expect("replicated curve has a profile");
    let csv = profile.to_csv().to_string();
    assert_eq!(csv.lines().next().unwrap(), documented_columns("serve_profile.csv").join(","));

    // sweep: one serve scenario, replicated.
    let grid = SweepGrid::new(&accel)
        .models(vec!["tiny"])
        .partitions(vec![1, 2])
        .bandwidth_scales(vec![1.0])
        .arrival_rates(vec![2000.0])
        .serve_duration(0.01)
        .serve_seed(3)
        .serve_replications(2)
        .steady_batches(2)
        .trace_samples(32);
    let report = SweepRunner::new(grid).run().unwrap();
    let csv = report.to_csv().to_string();
    assert_eq!(csv.lines().next().unwrap(), documented_columns("sweep_grid.csv").join(","));

    // cluster: two machines, replicated.
    let mut cfg = ClusterConfig::default();
    cfg.machines = vec![MachineConfig::new(64), MachineConfig::new(64)];
    cfg.serve.rates = vec![400.0];
    cfg.serve.duration_s = 0.02;
    cfg.serve.partitions = vec![2];
    cfg.serve.replications = 2;
    let out = ClusterSimulator::from_config(&accel, &graph, cfg).run().unwrap();
    let csv = out.to_csv().to_string();
    assert_eq!(csv.lines().next().unwrap(), documented_columns("cluster_machines.csv").join(","));
}
