//! Cluster-layer integration: the fleet-scale serving claims — load-aware
//! routing beats load-blind routing on a heterogeneous fleet at overload,
//! machine failures conserve every request, and reports are byte-identical
//! across worker-thread counts.

use trafficshape::cluster::{
    ClusterConfig, ClusterOutcome, ClusterSimulator, FailureEvent, MachineConfig, RouterPolicy,
};
use trafficshape::config::AcceleratorConfig;
use trafficshape::model::tiny_cnn;
use trafficshape::serve::{roofline_capacity_ips, ArrivalProcess, TenantSpec};

fn knl() -> AcceleratorConfig {
    AcceleratorConfig::knl_7210()
}

/// The headline heterogeneous fleet: a big fast machine, a mid-size one,
/// and a small machine with half the memory bandwidth.
fn heterogeneous_machines() -> Vec<MachineConfig> {
    vec![
        MachineConfig::new(64),
        MachineConfig::new(32).bw_scale(0.75),
        MachineConfig::new(16).bw_scale(0.5),
    ]
}

/// Offered fleet load as a multiple of the summed per-machine roofline
/// capacity, measured in-model so the tests track calibration changes.
fn fleet_rate(machines: &[MachineConfig], factor: f64) -> f64 {
    let base = knl();
    let graph = tiny_cnn();
    let cap: f64 = machines
        .iter()
        .enumerate()
        .map(|(m, mc)| roofline_capacity_ips(&mc.accel(&base, m), &graph))
        .sum();
    cap * factor
}

fn run_with_router(router: RouterPolicy, rate: f64, failures: Vec<FailureEvent>) -> ClusterOutcome {
    let mut cfg = ClusterConfig::default();
    cfg.machines = heterogeneous_machines();
    cfg.router = router;
    cfg.failures = failures;
    cfg.serve.rates = vec![rate];
    cfg.serve.duration_s = 0.08;
    cfg.serve.seed = 42;
    for mc in &mut cfg.machines {
        mc.serve.partitions = vec![2];
    }
    ClusterSimulator::from_config(&knl(), &tiny_cnn(), cfg).threads(2).run().unwrap()
}

#[test]
fn load_aware_routing_beats_round_robin_on_the_heterogeneous_fleet() {
    // At ~1.2× aggregate capacity, round-robin gives the 16-core
    // half-bandwidth machine the same third of the stream as the big
    // machine, so its backlog — and with it the pooled tail — explodes,
    // and draining it stretches the fleet makespan. Load-aware routing
    // spreads backlog by expected wait instead: strictly lower fleet
    // p99, and for po2c strictly higher goodput, on the same seeded
    // stream.
    let rate = fleet_rate(&heterogeneous_machines(), 1.2);
    let rr = run_with_router(RouterPolicy::RoundRobin, rate, Vec::new());
    let jsq = run_with_router(RouterPolicy::JoinShortestQueue, rate, Vec::new());
    let po2c = run_with_router(RouterPolicy::PowerOfTwoChoices, rate, Vec::new());

    for out in [&rr, &jsq, &po2c] {
        assert!(out.requests > 0);
        assert_eq!(out.fleet.served + out.fleet.dropped, out.requests);
    }
    assert!(
        jsq.fleet.latency.p99_ms < rr.fleet.latency.p99_ms,
        "jsq p99 {:.2} ms must beat round-robin {:.2} ms",
        jsq.fleet.latency.p99_ms,
        rr.fleet.latency.p99_ms
    );
    assert!(
        po2c.fleet.latency.p99_ms < rr.fleet.latency.p99_ms,
        "po2c p99 {:.2} ms must beat round-robin {:.2} ms",
        po2c.fleet.latency.p99_ms,
        rr.fleet.latency.p99_ms
    );
    assert!(
        po2c.fleet.goodput_ips > rr.fleet.goodput_ips,
        "po2c goodput {:.0} must beat round-robin {:.0}",
        po2c.fleet.goodput_ips,
        rr.fleet.goodput_ips
    );
}

#[test]
fn mid_run_failure_conserves_every_request() {
    // Machine 1 dies mid-window; its backlog re-enters the front door
    // and drains to the survivors. Nothing is lost: the per-machine
    // ledgers balance and the fleet serves-or-drops exactly the
    // front-door arrival count.
    let rate = fleet_rate(&heterogeneous_machines(), 1.3);
    let out = run_with_router(
        RouterPolicy::PowerOfTwoChoices,
        rate,
        vec![FailureEvent { machine: 1, at_s: 0.03, restart_s: None }],
    );
    assert_eq!(out.fleet.served + out.fleet.dropped, out.requests);
    for r in &out.machines {
        assert_eq!(
            r.routed + r.re_routed_in,
            r.served + r.dropped + r.re_routed_out,
            "machine {} ledger must balance",
            r.machine
        );
    }
    // At 1.3× overload the dead machine had a backlog to hand off.
    assert!(out.machines[1].re_routed_out > 0);
    assert_eq!(out.fleet.re_routed_in, out.fleet.re_routed_out);
    assert_eq!(out.machines[1].status, "failed");
    assert!(out.machines[1].availability < 1.0);
    assert!((out.machines[0].availability - 1.0).abs() < 1e-12);
}

#[test]
fn failure_with_restart_resumes_the_machine_and_still_conserves() {
    let rate = fleet_rate(&heterogeneous_machines(), 1.3);
    let out = run_with_router(
        RouterPolicy::PowerOfTwoChoices,
        rate,
        vec![FailureEvent { machine: 1, at_s: 0.02, restart_s: Some(0.05) }],
    );
    assert_eq!(out.fleet.served + out.fleet.dropped, out.requests);
    for r in &out.machines {
        assert_eq!(r.routed + r.re_routed_in, r.served + r.dropped + r.re_routed_out);
    }
    assert_eq!(out.machines[1].status, "restarted");
    // The machine served traffic again after coming back.
    assert!(out.machines[1].served > 0);
    // Down 30 ms of an 80 ms window.
    let expected = 1.0 - 0.03 / 0.08;
    assert!((out.machines[1].availability - expected).abs() < 1e-9);
}

#[test]
fn reports_are_byte_identical_across_thread_counts() {
    let rate = fleet_rate(&heterogeneous_machines(), 1.2);
    let run = |threads: usize| {
        let mut cfg = ClusterConfig::default();
        cfg.machines = heterogeneous_machines();
        cfg.serve.rates = vec![rate];
        cfg.serve.duration_s = 0.06;
        cfg.failures = vec![FailureEvent { machine: 0, at_s: 0.02, restart_s: Some(0.04) }];
        let out = ClusterSimulator::from_config(&knl(), &tiny_cnn(), cfg)
            .threads(threads)
            .run()
            .unwrap();
        (out.to_csv().to_string(), out.summary_json().to_string_pretty())
    };
    let one = run(1);
    assert_eq!(one, run(2));
    assert_eq!(one, run(4));
}

#[test]
fn placed_tenants_migrate_on_failure_and_conserve() {
    // Two tenants bin-packed over two machines; the machine hosting one
    // of them dies, the tenant migrates (paying its weight-transfer
    // bytes on the target), and every request is still accounted for.
    let mut cfg = ClusterConfig::default();
    cfg.machines = vec![MachineConfig::new(64), MachineConfig::new(64)];
    cfg.failures = vec![FailureEvent { machine: 0, at_s: 0.03, restart_s: None }];
    cfg.serve.duration_s = 0.08;
    cfg.serve.rates = Vec::new();
    cfg.serve.tenants = vec![
        TenantSpec::new(tiny_cnn(), 0.5, ArrivalProcess::poisson(300.0)),
        TenantSpec::new(tiny_cnn(), 0.5, ArrivalProcess::poisson(200.0)),
    ];
    let out = ClusterSimulator::from_config(&knl(), &tiny_cnn(), cfg).run().unwrap();

    assert_eq!(out.fleet.served + out.fleet.dropped, out.requests);
    for r in &out.machines {
        assert_eq!(r.routed + r.re_routed_in, r.served + r.dropped + r.re_routed_out);
    }
    assert!(!out.migrations.is_empty(), "the failed machine's tenant must move");
    let mig = &out.migrations[0];
    assert_eq!(mig.from, 0);
    assert_eq!(mig.to, 1);
    assert!(mig.weight_bytes > 0.0);
    assert!(out.machines[1].migrated_bytes > 0.0);
    // Everyone ends up on the survivor.
    assert_eq!(out.machines[1].placed_tenants.len(), 2);
    assert!(out.machines[0].placed_tenants.is_empty());
}
