//! Fixture battery for the staticcheck determinism auditor: every rule
//! must fire on a minimal violating snippet, must NOT fire when the
//! same hazard sits in `#[cfg(test)]` code, comments or string
//! literals, and must be silenced only by a reasoned
//! `staticcheck: allow` annotation. The allow marker below is split so
//! this file never registers directives of its own.

use trafficshape::analysis::{check_sources, Analysis, RULES};

const MARK: &str = concat!("// ", "staticcheck:");

fn check(files: &[(&str, &str)]) -> Analysis {
    let owned: Vec<(String, String)> =
        files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
    check_sources(&owned)
}

fn rules_fired(a: &Analysis) -> Vec<&'static str> {
    let mut r: Vec<&'static str> = a.violations.iter().map(|v| v.rule).collect();
    r.dedup();
    r
}

#[test]
fn r1_hash_collections_fire_in_library_code_only() {
    let bad = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = x(); }\n";
    let a = check(&[("src/sim/a.rs", bad)]);
    assert_eq!(rules_fired(&a), vec!["R1"]);
    assert_eq!(a.violations.len(), 2, "import line and use line");

    // The same text in a tests/ file, a cfg(test) mod, a comment or a
    // string literal is exempt.
    let a = check(&[("tests/a.rs", bad)]);
    assert!(a.clean(), "{}", a.render());
    let cfg = "#[cfg(test)]\nmod tests {\n    use std::collections::HashSet;\n}\n";
    let a = check(&[("src/a.rs", cfg)]);
    assert!(a.clean(), "{}", a.render());
    let masked = "// HashMap in prose\nfn f() { let s = \"HashMap\"; }\n";
    let a = check(&[("src/a.rs", masked)]);
    assert!(a.clean(), "{}", a.render());
}

#[test]
fn r2_wall_clock_fires_only_in_core_modules() {
    let bad = "fn f() { let t = std::time::Instant::now(); }\n";
    for core in ["src/sim/x.rs", "src/serve/x.rs", "src/sweep.rs", "src/cluster/x.rs"] {
        let a = check(&[(core, bad)]);
        assert_eq!(rules_fired(&a), vec!["R2"], "{core}");
    }
    // The measurement layer is outside the audited module set.
    let a = check(&[("src/coordinator/x.rs", bad)]);
    assert!(a.clean(), "{}", a.render());
    // Raw strings mask the pattern.
    let raw = "fn f() { let s = r#\"Instant::now SystemTime\"#; }\n";
    let a = check(&[("src/sim/x.rs", raw)]);
    assert!(a.clean(), "{}", a.render());
}

#[test]
fn r3_panic_paths_fire_outside_bins_and_tests() {
    let bad = "fn f() { x.unwrap(); y.expect(\"z\"); panic!(\"no\"); }\n";
    let a = check(&[("src/model/a.rs", bad)]);
    assert_eq!(rules_fired(&a), vec!["R3"]);
    assert_eq!(a.violations.len(), 3);
    // main.rs, src/bin/** and tests are allowed to panic.
    for exempt in ["src/main.rs", "src/bin/tool.rs", "tests/a.rs"] {
        let a = check(&[(exempt, bad)]);
        assert!(a.clean(), "{exempt}: {}", a.render());
    }
    // `.unwrap_or(` is not `.unwrap(`.
    let a = check(&[("src/model/a.rs", "fn f() { x.unwrap_or(1); }\n")]);
    assert!(a.clean(), "{}", a.render());
}

#[test]
fn r4_order_unpinned_folds_and_truncation_fire() {
    let sum = "fn f(m: &BTreeMap<u32, f64>) -> f64 { m.values().sum::<f64>() }\n";
    let a = check(&[("src/sim/a.rs", sum)]);
    assert_eq!(rules_fired(&a), vec!["R4"]);
    let trunc = "fn f(x: f64) -> usize { x as usize }\n";
    let a = check(&[("src/sim/a.rs", trunc)]);
    assert_eq!(rules_fired(&a), vec!["R4"]);
    // A slice fold is order-pinned; an integer cast is exact.
    let a = check(&[("src/sim/a.rs", "fn f(v: &[f64]) -> f64 { v.iter().sum::<f64>() }\n")]);
    assert!(a.clean(), "{}", a.render());
    let a = check(&[("src/sim/a.rs", "fn f(x: u32) -> usize { x as usize }\n")]);
    assert!(a.clean(), "{}", a.render());
}

#[test]
fn r5_orphaned_conservation_checks_fire_until_a_test_names_the_fn() {
    let sim = "fn drain() -> Result<()> {\n\
                   Err(Error::SimInvariant(\"leak\".into()))\n\
               }\n";
    let a = check(&[("src/sim/a.rs", sim)]);
    assert_eq!(rules_fired(&a), vec!["R5"]);
    assert!(a.violations[0].message.contains("drain"));

    // A test anywhere in the tree that names the fn clears it.
    let test = "#[test]\nfn covers() { drain(); }\n";
    let a = check(&[("src/sim/a.rs", sim), ("tests/it.rs", test)]);
    assert!(a.clean(), "{}", a.render());
    // ...but only as an identifier token, not a substring.
    let near_miss = "#[test]\nfn covers() { drained(); }\n";
    let a = check(&[("src/sim/a.rs", sim), ("tests/it.rs", near_miss)]);
    assert_eq!(rules_fired(&a), vec!["R5"]);
    // error.rs only defines the variant.
    let a = check(&[("src/error.rs", sim)]);
    assert!(a.clean(), "{}", a.render());
}

#[test]
fn r6_line_width_applies_everywhere_even_tests() {
    let wide = format!("fn f() {{}} // {}\n", "x".repeat(100));
    let a = check(&[("tests/a.rs", wide.as_str())]);
    assert_eq!(rules_fired(&a), vec!["R6"]);
    let a = check(&[("src/a.rs", "fn f() {}\n")]);
    assert!(a.clean(), "{}", a.render());
}

#[test]
fn r7_stepper_allocations_fire_outside_constructor_fns() {
    let bad = "fn step() {\n    let v: Vec<u32> = xs.collect();\n    let w = vec![0; 4];\n}\n";
    for hot in ["src/sim/step.rs", "src/sim/calendar.rs"] {
        let a = check(&[(hot, bad)]);
        assert_eq!(rules_fired(&a), vec!["R7"], "{hot}");
        assert_eq!(a.violations.len(), 2, "{hot}");
    }
    // The same text anywhere else is outside the hot-path scope.
    let a = check(&[("src/sim/engine.rs", bad)]);
    assert!(a.clean(), "{}", a.render());

    // Constructors and reset/seeding helpers may allocate.
    let ok = "impl S {\n\
                  fn new() -> S {\n        S { v: Vec::new() }\n    }\n\
                  fn reset(&mut self) {\n        self.v = vec![0; 4];\n    }\n\
                  fn from_scratch(t: T) -> S {\n        t.items.collect()\n    }\n\
                  fn with_traces(n: usize) -> S {\n        S { v: Vec::new() }\n    }\n\
              }\n";
    let a = check(&[("src/sim/step.rs", ok)]);
    assert!(a.clean(), "{}", a.render());

    // Test modules inside the hot-path files are exempt.
    let cfg = "#[cfg(test)]\nmod tests {\n    fn helper() -> Vec<u32> { Vec::new() }\n}\n";
    let a = check(&[("src/sim/calendar.rs", cfg)]);
    assert!(a.clean(), "{}", a.render());

    // A reasoned allow silences, like every other rule.
    let src = format!("fn step() {{ let v = vec![0; 4]; }} {MARK} allow(R7) -- fixture\n");
    let a = check(&[("src/sim/step.rs", src.as_str())]);
    assert!(a.clean(), "{}", a.render());
    assert!(a.allows[0].used);
}

#[test]
fn r8_unit_conflicts_fire_on_suffix_and_constructor_evidence() {
    // Adding seconds to milliseconds is the bug class R8 exists for.
    let a = check(&[("src/sim/a.rs", "fn f(a_s: f64, b_ms: f64) -> f64 { a_s + b_ms }\n")]);
    assert_eq!(rules_fired(&a), vec!["R8"]);
    assert!(a.violations[0].message.contains('s') && a.violations[0].message.contains("ms"));
    // Comparisons across units fire too.
    let a = check(&[("src/sim/a.rs", "fn f(x_ms: f64, y_s: f64) -> bool { x_ms < y_s }\n")]);
    assert_eq!(rules_fired(&a), vec!["R8"]);
    // A suffix that lies about what is assigned into it fires.
    let a = check(&[("src/sim/a.rs", "fn f(x_s: f64) { let y_ms: f64 = x_s; }\n")]);
    assert_eq!(rules_fired(&a), vec!["R8"]);
    // util::units constructors are argument sinks: from_ms wants ms.
    let bad = "fn f(x_s: f64) -> Seconds { Seconds::from_ms(x_s) }\n";
    let a = check(&[("src/sim/a.rs", bad)]);
    assert_eq!(rules_fired(&a), vec!["R8"]);
    // The core_flops-style hazard: a `_flops` name holding a rate.
    let bad = "fn f() { let x_flops: FlopsPerS = FlopsPerS::from_giga(1.0); g(x_flops); }\n";
    let a = check(&[("src/sim/a.rs", bad)]);
    assert_eq!(rules_fired(&a), vec!["R8"]);
}

#[test]
fn r8_stays_silent_without_conflicting_evidence() {
    // Same-unit arithmetic, unknown operands, and compatible rates
    // (events/s vs images/s) are all fine.
    for ok in [
        "fn f(a_s: f64, b_s: f64) -> f64 { a_s + b_s }\n",
        "fn f(a_s: f64, b: f64) -> f64 { a_s + b }\n",
        "fn f(thr_ips: f64, arrival_rate: f64) -> f64 { thr_ips - arrival_rate }\n",
        "fn f(total_bytes: f64, d_s: f64) -> f64 { Bytes(total_bytes).per(Seconds(d_s)).gb() }\n",
    ] {
        let a = check(&[("src/sim/a.rs", ok)]);
        assert!(a.clean(), "{ok}: {}", a.render());
    }
    let bad = "fn f(a_s: f64, b_ms: f64) -> f64 { a_s + b_ms }\n";
    // Test code, comments and strings are exempt; so is units.rs itself.
    let a = check(&[("tests/a.rs", bad)]);
    assert!(a.clean(), "{}", a.render());
    let cfg = format!("#[cfg(test)]\nmod tests {{\n    {bad}}}\n");
    let a = check(&[("src/sim/a.rs", cfg.as_str())]);
    assert!(a.clean(), "{}", a.render());
    let masked = "// a_s + b_ms in prose\nfn f() { let s = \"a_s + b_ms\"; }\n";
    let a = check(&[("src/sim/a.rs", masked)]);
    assert!(a.clean(), "{}", a.render());
    let a = check(&[("src/util/units.rs", bad)]);
    assert!(a.clean(), "{}", a.render());
    // A reasoned allow silences and is inventoried.
    let src =
        format!("fn f(a_s: f64, b_ms: f64) -> f64 {{ a_s + b_ms }} {MARK} allow(R8) -- fix\n");
    let a = check(&[("src/sim/a.rs", src.as_str())]);
    assert!(a.clean(), "{}", a.render());
    assert!(a.allows[0].used);
}

#[test]
fn r9_raw_conversion_constants_fire_in_arithmetic_only() {
    for bad in [
        "fn f(t_ms: f64) -> f64 { t_ms / 1e3 }\n",
        "fn f(b: f64) -> f64 { b / 1e9 }\n",
        "fn f(s: f64) -> f64 { s * 1e6 }\n",
        "fn f(k: f64) -> f64 { k * 1024.0 }\n",
    ] {
        let a = check(&[("src/sim/a.rs", bad)]);
        assert_eq!(rules_fired(&a), vec!["R9"], "{bad}: {}", a.render());
    }
    // Comparisons, call arguments and non-scale floats are not
    // conversions; units.rs, tests and masked text are out of scope.
    for ok in [
        "fn f(x: f64) -> bool { x > 1e9 }\n",
        "fn f() { g(1e6); }\n",
        "fn f(x: f64) -> f64 { x * 2.0 }\n",
    ] {
        let a = check(&[("src/sim/a.rs", ok)]);
        assert!(a.clean(), "{ok}: {}", a.render());
    }
    let bad = "fn f(t_ms: f64) -> f64 { t_ms / 1e3 }\n";
    let a = check(&[("src/util/units.rs", bad)]);
    assert!(a.clean(), "{}", a.render());
    let a = check(&[("tests/a.rs", bad)]);
    assert!(a.clean(), "{}", a.render());
    let masked = "// t / 1e3 in prose\nfn f() { let s = \"x / 1e9\"; }\n";
    let a = check(&[("src/sim/a.rs", masked)]);
    assert!(a.clean(), "{}", a.render());
    // A reasoned allow silences (the stats.rs tolerance pattern).
    let src = format!("fn f(x: f64) -> f64 {{ x * 1e-9 }} {MARK} allow(R9) -- tolerance\n");
    let a = check(&[("src/sim/a.rs", src.as_str())]);
    assert!(a.clean(), "{}", a.render());
    assert!(a.allows[0].used);
}

#[test]
fn reasoned_allow_silences_and_is_inventoried() {
    let src = format!(
        "fn f() {{ x.unwrap(); }} {MARK} allow(R3) -- fixture justification\n"
    );
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert!(a.clean(), "{}", a.render());
    assert_eq!(a.allows.len(), 1);
    assert_eq!(a.allows[0].rule, "R3");
    assert_eq!(a.allows[0].reason, "fixture justification");
    assert!(a.allows[0].used);
    assert!(a.unused_allows().is_empty());

    // A standalone annotation line covers the next line.
    let src = format!("{MARK} allow(R3) -- next-line form\nfn f() {{ x.unwrap(); }}\n");
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert!(a.clean(), "{}", a.render());

    // An allow for the wrong rule does not silence.
    let src = format!("fn f() {{ x.unwrap(); }} {MARK} allow(R1) -- wrong rule\n");
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert_eq!(rules_fired(&a), vec!["R3"]);
    assert!(!a.allows[0].used, "the mismatched allow is reported unused");
    assert_eq!(a.unused_allows().len(), 1);
}

#[test]
fn malformed_or_unknown_suppressions_are_r0_and_unsuppressible() {
    // Missing reason.
    let src = format!("fn f() {{ x.unwrap(); }} {MARK} allow(R3)\n");
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert_eq!(rules_fired(&a), vec!["R0", "R3"]);

    // Unknown rule id.
    let src = format!("fn f() {{}} {MARK} allow(R42) -- no such rule\n");
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert_eq!(rules_fired(&a), vec!["R0"]);

    // R0 cannot be annotated away, even with a well-formed allow(R0).
    let src = format!("{MARK} allow(R0) -- nice try\nfn f() {{}} {MARK} allow(R3)\n");
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert!(rules_fired(&a).contains(&"R0"), "{}", a.render());

    // Doc comments may discuss the grammar without invoking it.
    let src = "/// {} allow(R3) -- prose, not a directive\nfn f() {}\n"
        .replace("{}", MARK.trim_start_matches("// "));
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert!(a.clean(), "{}", a.render());
    assert!(a.allows.is_empty());
}

#[test]
fn unused_allows_are_reported_but_not_fatal() {
    let src = format!("fn f() {{}} {MARK} allow(R3) -- nothing here anymore\n");
    let a = check(&[("src/model/a.rs", src.as_str())]);
    assert!(a.clean());
    assert_eq!(a.unused_allows().len(), 1);
    assert!(a.render().contains("unused allow(R3)"));
    let j = a.to_json().to_string_pretty();
    assert!(j.contains("\"unused_allows\": 1"));
    assert!(j.contains("\"clean\": true"));
}

#[test]
fn registry_is_complete_and_deterministically_ordered() {
    let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec!["R0", "R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8", "R9"]);
    // Violations come back sorted by (file, line, rule).
    let a = check(&[
        ("src/sim/b.rs", "fn g() { x.unwrap(); }\nuse std::collections::HashMap;\n"),
        ("src/sim/a.rs", "fn f() { let t = std::time::Instant::now(); }\n"),
    ]);
    let got: Vec<(String, usize, &str)> =
        a.violations.iter().map(|v| (v.file.clone(), v.line, v.rule)).collect();
    let mut sorted = got.clone();
    sorted.sort();
    assert_eq!(got, sorted);
    assert_eq!(a.files, vec!["src/sim/a.rs", "src/sim/b.rs"]);
}
