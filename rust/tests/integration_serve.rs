//! Serving-scenario integration: the repo's headline serving claim —
//! under heavy request traffic, asynchronous partitions turn the paper's
//! throughput gain into strictly lower tail latency — plus the
//! determinism bar every serve report must clear.

use trafficshape::config::AcceleratorConfig;
use trafficshape::model::resnet50;
use trafficshape::serve::{ArrivalKind, ArrivalProcess, ServeExperiment, ServeSimulator};
use trafficshape::shaping::PartitionExperiment;

fn knl() -> AcceleratorConfig {
    AcceleratorConfig::knl_7210()
}

/// Measured synchronous throughput (img/s) of the offline baseline —
/// the serving capacity of the unpartitioned machine, measured in-sim so
/// the arrival rates below track any calibration change.
fn sync_capacity_ips() -> f64 {
    let accel = knl();
    let base = PartitionExperiment::new(&accel, &resnet50())
        .steady_batches(3)
        .trace_samples(64)
        .run_baseline()
        .unwrap();
    base.throughput
}

#[test]
fn four_async_partitions_beat_sync_p99_under_heavy_load() {
    // The acceptance bar: at a fixed seed and an arrival rate above the
    // synchronous capacity (open-loop overload, the regime the ROADMAP's
    // "heavy traffic" north star cares about), 4 asynchronous partitions
    // must achieve strictly lower p99 latency than the 1-partition
    // synchronous baseline — the paper's +8% throughput gain compounding
    // into a shorter backlog every second of the window.
    let accel = knl();
    let graph = resnet50();
    let capacity = sync_capacity_ips();
    let rate = capacity * 1.2;
    let duration = 600.0 / rate; // ≈ 600 requests at any calibration
    let run = |partitions: usize| {
        ServeSimulator::new(&accel, &graph)
            .partitions(partitions)
            .arrival(ArrivalProcess::poisson(rate))
            .duration(duration)
            .seed(7)
            .trace_samples(128)
            .run()
            .unwrap()
    };
    let sync = run(1);
    let part = run(4);

    // Same stream, fully drained on both machines.
    assert_eq!(sync.requests, part.requests);
    assert!(sync.requests > 300, "want a heavy stream, got {}", sync.requests);
    assert_eq!(sync.latency.count, sync.requests);
    assert_eq!(part.latency.count, part.requests);

    assert!(
        part.latency.p99_ms < sync.latency.p99_ms,
        "4 async partitions must beat sync p99: {:.1} ms vs {:.1} ms",
        part.latency.p99_ms,
        sync.latency.p99_ms
    );
    // The mechanism: higher sustained throughput drains the overload
    // backlog faster (the paper's relative-performance gain, serving
    // edition).
    assert!(
        part.throughput_ips > sync.throughput_ips,
        "partitioned throughput {:.0} must beat sync {:.0}",
        part.throughput_ips,
        sync.throughput_ips
    );
}

#[test]
fn serve_report_is_byte_identical_across_thread_counts() {
    // Acceptance bar #2: the serve report (rendered table, CSV, JSON)
    // must not depend on the worker pool size.
    let accel = knl();
    let graph = resnet50();
    let run = |threads: usize| {
        ServeExperiment::new(&accel, &graph)
            .partitions(vec![1, 2, 4])
            .rates(vec![300.0, 700.0])
            .duration(0.15)
            .seed(42)
            .trace_samples(64)
            .threads(threads)
            .run()
            .unwrap()
    };
    let serial = run(1);
    for threads in [2, 4, 8] {
        let parallel = run(threads);
        assert_eq!(serial.render(), parallel.render(), "render differs at {threads} threads");
        assert_eq!(
            serial.to_csv().to_string(),
            parallel.to_csv().to_string(),
            "csv differs at {threads} threads"
        );
        assert_eq!(
            serial.summary_json().to_string_pretty(),
            parallel.summary_json().to_string_pretty(),
            "summary differs at {threads} threads"
        );
    }
}

#[test]
fn bursty_arrivals_inflate_tail_latency() {
    // Same mean load, burstier process ⇒ strictly worse p99: the tail is
    // where statistical traffic shaping has to earn its keep.
    let accel = knl();
    let graph = resnet50();
    let rate = sync_capacity_ips() * 0.7;
    let run = |kind: ArrivalKind| {
        ServeExperiment::new(&accel, &graph)
            .partitions(vec![2])
            .rates(vec![rate])
            .arrival(kind)
            .duration(0.6)
            .seed(11)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let poisson = run(ArrivalKind::Poisson);
    let bursty = run(ArrivalKind::Bursty { burstiness: 8.0, mean_burst_s: 0.1 });
    let p = poisson.at(rate, 2).unwrap().latency.p99_ms;
    let b = bursty.at(rate, 2).unwrap().latency.p99_ms;
    assert!(b > p * 1.1, "bursty p99 {b:.1} ms should dwarf poisson p99 {p:.1} ms");
}

#[test]
fn serve_outcome_is_seed_deterministic() {
    let accel = knl();
    let graph = resnet50();
    let run = |seed: u64| {
        ServeSimulator::new(&accel, &graph)
            .partitions(2)
            .arrival(ArrivalProcess::poisson(400.0))
            .duration(0.2)
            .seed(seed)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.total_bytes, b.total_bytes);
    let c = run(6);
    assert!(a.requests != c.requests || a.latency != c.latency, "seed must matter");
}

#[test]
fn bounded_slo_run_sheds_load_and_beats_unbounded_p99() {
    // The overload acceptance bar: at a fixed seed and a rate well above
    // the synchronous capacity, the bounded-queue + SLO run must report
    // nonzero drops and a strictly lower p99 than the legacy unbounded
    // run — overload becomes a measured goodput/drop trade-off instead
    // of an unbounded-latency artifact.
    let accel = knl();
    let graph = resnet50();
    let capacity = sync_capacity_ips();
    let rate = capacity * 2.0;
    let duration = 400.0 / rate; // ≈ 400 requests at any calibration
    let run = |sim: ServeSimulator| {
        sim.partitions(2)
            .arrival(ArrivalProcess::poisson(rate))
            .duration(duration)
            .seed(7)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let unbounded = run(ServeSimulator::new(&accel, &graph));
    let bounded = run(ServeSimulator::new(&accel, &graph).queue_cap(4).slo_ms(250.0));

    // Same stream on both machines.
    assert_eq!(unbounded.requests, bounded.requests);
    assert!(unbounded.requests > 200, "want a heavy stream, got {}", unbounded.requests);
    assert_eq!(unbounded.dropped, 0, "legacy run drops nothing");
    assert_eq!(unbounded.served, unbounded.requests);

    assert!(bounded.dropped > 0, "2x overload against cap 4 must shed load");
    assert_eq!(bounded.served + bounded.dropped, bounded.requests);
    assert_eq!(bounded.latency.count, bounded.served);
    assert!(bounded.queue_peak <= 4, "queue peak {} over cap", bounded.queue_peak);
    assert!(
        bounded.latency.p99_ms < unbounded.latency.p99_ms,
        "bounded p99 {:.1} ms must beat unbounded {:.1} ms",
        bounded.latency.p99_ms,
        unbounded.latency.p99_ms
    );
    assert!(bounded.goodput_ips <= bounded.throughput_ips + 1e-9);
    assert!(bounded.drop_rate > 0.0 && bounded.drop_rate < 1.0);
}

#[test]
fn overload_controls_keep_reports_deterministic() {
    // The determinism bar extends to the overload path: bounded + SLO +
    // batch-timeout runs must stay byte-identical for a fixed seed.
    let accel = knl();
    let graph = resnet50();
    let run = || {
        ServeSimulator::new(&accel, &graph)
            .partitions(2)
            .arrival(ArrivalProcess::poisson(sync_capacity_ips() * 1.5))
            .duration(0.3)
            .seed(21)
            .queue_cap(6)
            .slo_ms(150.0)
            .batch_timeout_ms(2.0)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.served, b.served);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.total_bytes, b.total_bytes);
}
