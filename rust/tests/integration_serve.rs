//! Serving-scenario integration: the repo's headline serving claim —
//! under heavy request traffic, asynchronous partitions turn the paper's
//! throughput gain into strictly lower tail latency — plus the
//! determinism bar every serve report must clear.

use trafficshape::config::AcceleratorConfig;
use trafficshape::model::resnet50;
use trafficshape::serve::{
    AdaptiveConfig, ArrivalKind, ArrivalProcess, RateShape, ServeExperiment, ServeSimulator,
};
use trafficshape::shaping::PartitionExperiment;

fn knl() -> AcceleratorConfig {
    AcceleratorConfig::knl_7210()
}

/// Measured synchronous throughput (img/s) of the offline baseline —
/// the serving capacity of the unpartitioned machine, measured in-sim so
/// the arrival rates below track any calibration change.
fn sync_capacity_ips() -> f64 {
    let accel = knl();
    let base = PartitionExperiment::new(&accel, &resnet50())
        .steady_batches(3)
        .trace_samples(64)
        .run_baseline()
        .unwrap();
    base.throughput
}

#[test]
fn four_async_partitions_beat_sync_p99_under_heavy_load() {
    // The acceptance bar: at a fixed seed and an arrival rate above the
    // synchronous capacity (open-loop overload, the regime the ROADMAP's
    // "heavy traffic" north star cares about), 4 asynchronous partitions
    // must achieve strictly lower p99 latency than the 1-partition
    // synchronous baseline — the paper's +8% throughput gain compounding
    // into a shorter backlog every second of the window.
    let accel = knl();
    let graph = resnet50();
    let capacity = sync_capacity_ips();
    let rate = capacity * 1.2;
    let duration = 600.0 / rate; // ≈ 600 requests at any calibration
    let run = |partitions: usize| {
        ServeSimulator::new(&accel, &graph)
            .partitions(partitions)
            .arrival(ArrivalProcess::poisson(rate))
            .duration(duration)
            .seed(7)
            .trace_samples(128)
            .run()
            .unwrap()
    };
    let sync = run(1);
    let part = run(4);

    // Same stream, fully drained on both machines.
    assert_eq!(sync.requests, part.requests);
    assert!(sync.requests > 300, "want a heavy stream, got {}", sync.requests);
    assert_eq!(sync.latency.count, sync.requests);
    assert_eq!(part.latency.count, part.requests);

    assert!(
        part.latency.p99_ms < sync.latency.p99_ms,
        "4 async partitions must beat sync p99: {:.1} ms vs {:.1} ms",
        part.latency.p99_ms,
        sync.latency.p99_ms
    );
    // The mechanism: higher sustained throughput drains the overload
    // backlog faster (the paper's relative-performance gain, serving
    // edition).
    assert!(
        part.throughput_ips > sync.throughput_ips,
        "partitioned throughput {:.0} must beat sync {:.0}",
        part.throughput_ips,
        sync.throughput_ips
    );
}

#[test]
fn serve_report_is_byte_identical_across_thread_counts() {
    // Acceptance bar #2: the serve report (rendered table, CSV, JSON)
    // must not depend on the worker pool size.
    let accel = knl();
    let graph = resnet50();
    let run = |threads: usize| {
        ServeExperiment::new(&accel, &graph)
            .partitions(vec![1, 2, 4])
            .rates(vec![300.0, 700.0])
            .duration(0.15)
            .seed(42)
            .trace_samples(64)
            .threads(threads)
            .run()
            .unwrap()
    };
    let serial = run(1);
    for threads in [2, 4, 8] {
        let parallel = run(threads);
        assert_eq!(serial.render(), parallel.render(), "render differs at {threads} threads");
        assert_eq!(
            serial.to_csv().to_string(),
            parallel.to_csv().to_string(),
            "csv differs at {threads} threads"
        );
        assert_eq!(
            serial.summary_json().to_string_pretty(),
            parallel.summary_json().to_string_pretty(),
            "summary differs at {threads} threads"
        );
    }
}

#[test]
fn bursty_arrivals_inflate_tail_latency() {
    // Same mean load, burstier process ⇒ strictly worse p99: the tail is
    // where statistical traffic shaping has to earn its keep.
    let accel = knl();
    let graph = resnet50();
    let rate = sync_capacity_ips() * 0.7;
    let run = |kind: ArrivalKind| {
        ServeExperiment::new(&accel, &graph)
            .partitions(vec![2])
            .rates(vec![rate])
            .arrival(kind)
            .duration(0.6)
            .seed(11)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let poisson = run(ArrivalKind::Poisson);
    let bursty = run(ArrivalKind::Bursty { burstiness: 8.0, mean_burst_s: 0.1 });
    let p = poisson.at(rate, 2).unwrap().latency.p99_ms;
    let b = bursty.at(rate, 2).unwrap().latency.p99_ms;
    assert!(b > p * 1.1, "bursty p99 {b:.1} ms should dwarf poisson p99 {p:.1} ms");
}

#[test]
fn serve_outcome_is_seed_deterministic() {
    let accel = knl();
    let graph = resnet50();
    let run = |seed: u64| {
        ServeSimulator::new(&accel, &graph)
            .partitions(2)
            .arrival(ArrivalProcess::poisson(400.0))
            .duration(0.2)
            .seed(seed)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.total_bytes, b.total_bytes);
    let c = run(6);
    assert!(a.requests != c.requests || a.latency != c.latency, "seed must matter");
}

#[test]
fn bounded_slo_run_sheds_load_and_beats_unbounded_p99() {
    // The overload acceptance bar: at a fixed seed and a rate well above
    // the synchronous capacity, the bounded-queue + SLO run must report
    // nonzero drops and a strictly lower p99 than the legacy unbounded
    // run — overload becomes a measured goodput/drop trade-off instead
    // of an unbounded-latency artifact.
    let accel = knl();
    let graph = resnet50();
    let capacity = sync_capacity_ips();
    let rate = capacity * 2.0;
    let duration = 400.0 / rate; // ≈ 400 requests at any calibration
    let run = |sim: ServeSimulator| {
        sim.partitions(2)
            .arrival(ArrivalProcess::poisson(rate))
            .duration(duration)
            .seed(7)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let unbounded = run(ServeSimulator::new(&accel, &graph));
    let bounded = run(ServeSimulator::new(&accel, &graph).queue_cap(4).slo_ms(250.0));

    // Same stream on both machines.
    assert_eq!(unbounded.requests, bounded.requests);
    assert!(unbounded.requests > 200, "want a heavy stream, got {}", unbounded.requests);
    assert_eq!(unbounded.dropped, 0, "legacy run drops nothing");
    assert_eq!(unbounded.served, unbounded.requests);

    assert!(bounded.dropped > 0, "2x overload against cap 4 must shed load");
    assert_eq!(bounded.served + bounded.dropped, bounded.requests);
    assert_eq!(bounded.latency.count, bounded.served);
    assert!(bounded.queue_peak <= 4, "queue peak {} over cap", bounded.queue_peak);
    assert!(
        bounded.latency.p99_ms < unbounded.latency.p99_ms,
        "bounded p99 {:.1} ms must beat unbounded {:.1} ms",
        bounded.latency.p99_ms,
        unbounded.latency.p99_ms
    );
    assert!(bounded.goodput_ips <= bounded.throughput_ips + 1e-9);
    assert!(bounded.drop_rate > 0.0 && bounded.drop_rate < 1.0);
}

#[test]
fn adaptive_repartitioning_reconfigures_and_competes_under_step_load() {
    // The runtime-mutable-topology acceptance bar: under a low→high→low
    // step rate profile (low phases far below the synchronous capacity,
    // the high phase far above it), the adaptive run must (a) actually
    // re-partition at least once, (b) strictly beat the worst static
    // partition count on BOTH p99 and goodput, and (c) match (within
    // 10%) or beat the best static count on p99 OR goodput — it pays at
    // most a one-epoch reaction penalty for not knowing the load curve
    // in advance.
    let accel = knl();
    let graph = resnet50();
    let capacity = sync_capacity_ips();
    let period = 240.0 / capacity; // low [0, P/2), high [P/2, P), low [P, 1.5P)
    let profile = ArrivalProcess::step_profile(0.2 * capacity, 3.0 * capacity, period);
    let duration = 1.5 * period;
    let epoch = period / 8.0;
    let base = |partitions: usize| {
        ServeSimulator::new(&accel, &graph)
            .partitions(partitions)
            .arrival(profile)
            .duration(duration)
            .seed(7)
            .trace_samples(64)
    };
    let s1 = base(1).run().unwrap();
    let s4 = base(4).run().unwrap();
    // A 2% confirmed-gain threshold: the paper's ~8% partitioned
    // throughput gain must clear it comfortably, so the climb sticks.
    let controller = AdaptiveConfig::new(vec![1, 4]).epoch_s(epoch).min_gain_step(0.02);
    let adaptive = base(1).adaptive(controller).run().unwrap();

    // Same stream everywhere; nothing dropped (unbounded queues), so
    // conservation is exact across every reconfiguration.
    assert_eq!(adaptive.requests, s1.requests);
    assert_eq!(adaptive.requests, s4.requests);
    assert!(adaptive.requests > 300, "want a heavy stream, got {}", adaptive.requests);
    assert_eq!(adaptive.served + adaptive.dropped, adaptive.requests);
    assert_eq!(adaptive.served, adaptive.requests, "unbounded adaptive run drops nothing");
    for e in &adaptive.epochs {
        assert!(e.is_conserving(), "epoch leaks requests: {e:?}");
    }

    // (a) The step must trigger online re-partitioning, and the high
    // phase must be met with more partitions than the low start.
    assert!(
        adaptive.reconfigurations() >= 1,
        "step load must reconfigure; trajectory {:?}",
        adaptive.partition_trajectory()
    );
    assert!(
        adaptive.partition_trajectory().contains(&4),
        "the overloaded phase must climb to 4 partitions: {:?}",
        adaptive.partition_trajectory()
    );

    // (b) Strictly better than the worst static choice on both axes.
    let worst_p99 = s1.latency.p99_ms.max(s4.latency.p99_ms);
    let worst_goodput = s1.goodput_ips.min(s4.goodput_ips);
    assert!(
        adaptive.latency.p99_ms < worst_p99,
        "adaptive p99 {:.1} ms must beat the worst static {:.1} ms",
        adaptive.latency.p99_ms,
        worst_p99
    );
    assert!(
        adaptive.goodput_ips > worst_goodput,
        "adaptive goodput {:.0} must beat the worst static {:.0}",
        adaptive.goodput_ips,
        worst_goodput
    );

    // (c) And competitive with the best static choice on at least one.
    let best_p99 = s1.latency.p99_ms.min(s4.latency.p99_ms);
    let best_goodput = s1.goodput_ips.max(s4.goodput_ips);
    assert!(
        adaptive.latency.p99_ms <= 1.10 * best_p99 || adaptive.goodput_ips >= 0.90 * best_goodput,
        "adaptive (p99 {:.1} ms, goodput {:.0}) must match the best static \
         (p99 {:.1} ms, goodput {:.0}) within 10% on one axis",
        adaptive.latency.p99_ms,
        adaptive.goodput_ips,
        best_p99,
        best_goodput
    );
}

#[test]
fn adaptive_single_candidate_reproduces_the_fixed_outcome_exactly() {
    // With one candidate the controller can never reconfigure, so the
    // adaptive entry point must be indistinguishable from the fixed
    // path — same latencies, same makespan, same trace bytes.
    let accel = knl();
    let graph = resnet50();
    let rate = sync_capacity_ips() * 0.8;
    let run = |adaptive: bool| {
        let sim = ServeSimulator::new(&accel, &graph)
            .partitions(2)
            .arrival(ArrivalProcess::poisson(rate))
            .duration(0.2)
            .seed(13)
            .trace_samples(64);
        let sim = if adaptive { sim.adaptive(AdaptiveConfig::new(vec![2])) } else { sim };
        sim.run().unwrap()
    };
    let fixed = run(false);
    let adaptive = run(true);
    assert_eq!(adaptive.partitions, fixed.partitions);
    assert_eq!(adaptive.requests, fixed.requests);
    assert_eq!(adaptive.served, fixed.served);
    assert_eq!(adaptive.dropped, fixed.dropped);
    assert_eq!(adaptive.batches, fixed.batches);
    assert_eq!(adaptive.queue_peak, fixed.queue_peak);
    assert_eq!(adaptive.latency, fixed.latency);
    assert_eq!(adaptive.makespan_s, fixed.makespan_s);
    assert_eq!(adaptive.throughput_ips, fixed.throughput_ips);
    assert_eq!(adaptive.goodput_ips, fixed.goodput_ips);
    assert_eq!(adaptive.total_bytes, fixed.total_bytes);
    assert_eq!(adaptive.bw, fixed.bw);
    assert_eq!(adaptive.reconfigurations(), 0);
    assert_eq!(adaptive.partition_trajectory(), vec![2]);
}

#[test]
fn adaptive_serve_grid_is_deterministic_across_thread_counts() {
    // The determinism bar extends to adaptive rows in the serve grid:
    // --threads 1 and --threads N must render byte-identical reports.
    let accel = knl();
    let graph = resnet50();
    let capacity = sync_capacity_ips();
    let run = |threads: usize| {
        ServeExperiment::new(&accel, &graph)
            .partitions(vec![1, 2])
            .rates(vec![capacity * 0.9])
            .arrival(ArrivalKind::Piecewise {
                rate_lo: 0.3,
                rate_hi: 1.5,
                period_s: 0.1,
                shape: RateShape::Step,
            })
            .duration(0.15)
            .seed(42)
            .trace_samples(64)
            .threads(threads)
            .adaptive(AdaptiveConfig::new(vec![1, 2]).epoch_s(0.025))
            .run()
            .unwrap()
    };
    let serial = run(1);
    for threads in [2, 4] {
        let parallel = run(threads);
        assert_eq!(serial.render(), parallel.render(), "render differs at {threads} threads");
        assert_eq!(
            serial.to_csv().to_string(),
            parallel.to_csv().to_string(),
            "csv differs at {threads} threads"
        );
        assert_eq!(
            serial.summary_json().to_string_pretty(),
            parallel.summary_json().to_string_pretty(),
            "summary differs at {threads} threads"
        );
    }
}

#[test]
fn overload_controls_keep_reports_deterministic() {
    // The determinism bar extends to the overload path: bounded + SLO +
    // batch-timeout runs must stay byte-identical for a fixed seed.
    let accel = knl();
    let graph = resnet50();
    let run = || {
        ServeSimulator::new(&accel, &graph)
            .partitions(2)
            .arrival(ArrivalProcess::poisson(sync_capacity_ips() * 1.5))
            .duration(0.3)
            .seed(21)
            .queue_cap(6)
            .slo_ms(150.0)
            .batch_timeout_ms(2.0)
            .trace_samples(64)
            .run()
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.served, b.served);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.total_bytes, b.total_bytes);
}
