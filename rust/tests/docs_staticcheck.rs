//! docs/STATICCHECK.md is a contract, not prose: the rule table is
//! compared here against the registry the auditor actually enforces
//! (`trafficshape::analysis::RULES`), and the documented command lines
//! and suppression marker are checked against the binary's interface.
//! Any drift fails this test (and CI's docs job).

use trafficshape::analysis::units_rule::SUFFIXES;
use trafficshape::analysis::{check_sources, rule_info, RULES};

const DOC: &str = include_str!("../../docs/STATICCHECK.md");

/// `(id, title)` pairs from the "Rule catalog" table: the first two
/// backticked/plain cells of each `| \`R..\` |` row.
fn documented_rules() -> Vec<(String, String)> {
    DOC.lines()
        .filter(|l| l.starts_with("| `R"))
        .map(|l| {
            let mut cells = l.split('|').map(str::trim);
            cells.next(); // leading empty cell
            let id = cells.next().expect("rule id cell").trim_matches('`').to_string();
            let title = cells.next().expect("title cell").to_string();
            (id, title)
        })
        .collect()
}

#[test]
fn rule_table_matches_the_registry() {
    let documented = documented_rules();
    let registry: Vec<(String, String)> =
        RULES.iter().map(|r| (r.id.to_string(), r.title.to_string())).collect();
    assert_eq!(
        documented, registry,
        "docs/STATICCHECK.md rule catalog disagrees with analysis::RULES — \
         update the table and the registry together"
    );
}

#[test]
fn every_registry_rule_resolves_and_is_documented_in_prose() {
    for r in RULES {
        assert!(rule_info(r.id).is_some(), "registry self-lookup for {}", r.id);
        assert!(
            DOC.contains(&format!("`{}`", r.id)),
            "docs/STATICCHECK.md never mentions rule {}",
            r.id
        );
    }
}

/// `(suffix, label)` pairs from the "identifier-suffix grammar" table:
/// the backticked cells of each `| \`_..\` |` row.
fn documented_suffixes() -> Vec<(String, String)> {
    DOC.lines()
        .filter(|l| l.starts_with("| `_"))
        .map(|l| {
            let mut cells = l.split('|').map(str::trim);
            cells.next(); // leading empty cell
            let suffix = cells.next().expect("suffix cell").trim_matches('`').to_string();
            let label = cells.next().expect("label cell").trim_matches('`').to_string();
            (suffix, label)
        })
        .collect()
}

#[test]
fn suffix_table_matches_the_grammar() {
    let documented = documented_suffixes();
    let grammar: Vec<(String, String)> =
        SUFFIXES.iter().map(|&(s, l)| (s.to_string(), l.to_string())).collect();
    assert_eq!(
        documented, grammar,
        "docs/STATICCHECK.md suffix table disagrees with units_rule::SUFFIXES — \
         update the table and the grammar together (order matters: longest-match)"
    );
}

#[test]
fn documented_command_and_marker_are_real() {
    assert!(
        DOC.contains("cargo run --release --bin staticcheck -- --root rust"),
        "the documented invocation must match CI's"
    );
    // The documented suppression marker must actually parse: a file
    // using exactly the documented grammar audits clean.
    let src = "fn f() -> Result<(), ()> {\n\
                   let x: Option<u32> = Some(1);\n\
                   // staticcheck: allow(R3) -- documented example\n\
                   let _ = x.unwrap();\n\
                   Ok(())\n\
               }\n";
    let a = check_sources(&[("src/doc_example.rs".to_string(), src.to_string())]);
    assert!(a.clean(), "documented grammar must suppress: {}", a.render());
    assert_eq!(a.allows.len(), 1);
    assert!(a.allows[0].used);
}
