//! Offline stub of the `xla` PJRT bindings.
//!
//! The trafficshape runtime (`runtime::client`) is written against the
//! real `xla` crate's API: `PjRtClient::cpu()` → `compile` → `execute`.
//! That crate links libxla, which is unavailable in the offline build
//! environment, so this stub provides the same surface with every entry
//! point returning a descriptive error at runtime. The simulator,
//! shaping, sweep and experiment layers never touch it; only the
//! `e2e`/coordinator path does, and it reports
//! "xla backend not available" instead of failing to link.
//!
//! To enable real execution, point the `xla` dependency of the
//! `trafficshape` crate at the actual bindings — no call-site changes
//! are needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`: a message, Display + std::error.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(
            "xla backend not available: trafficshape was built against the offline \
             xla stub (swap rust/xla-stub for the real bindings to run e2e)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (tensor value). All conversions fail in the stub.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// The PJRT client. `cpu()` is the stub's single point of failure: every
/// downstream call site is unreachable once construction errors.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module proto (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla backend not available"));
    }
}
