//! Partition sweep over any model set — a configurable Fig-5.
//!
//! ```bash
//! cargo run --release --example partition_sweep -- \
//!     --models resnet50,googlenet --partitions 1,2,4,8,16 --batches 6
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::config::AcceleratorConfig;
use trafficshape::error::Error;
use trafficshape::model;
use trafficshape::shaping::PartitionExperiment;
use trafficshape::util::table::Table;

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("partition_sweep", "sweep partition counts over models")
        .opt("models", "LIST", Some("resnet50"), "comma-separated model names")
        .opt("partitions", "LIST", Some("1,2,4,8,16"), "partition counts")
        .opt("batches", "N", Some("6"), "steady-state batches")
        .opt("accel", "NAME", Some("knl_7210"), "accelerator preset");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };

    let run = || -> trafficshape::error::Result<()> {
        let accel = AcceleratorConfig::preset(m.get("accel").unwrap())?;
        let batches = m.get_usize("batches")?.unwrap();
        let parts = m.get_usize_list("partitions")?.unwrap();
        let models = m.get_str_list("models").unwrap();

        let mut t = Table::new(vec!["model", "n", "rel perf", "σ reduction", "avg BW gain"])
            .left_first();
        for name in &models {
            let graph = model::by_name(name)?;
            for &n in &parts {
                if n == 1 {
                    continue;
                }
                match PartitionExperiment::new(&accel, &graph)
                    .partitions(n)
                    .steady_batches(batches)
                    .run()
                {
                    Ok(r) => t.row(vec![
                        name.clone(),
                        n.to_string(),
                        format!("{:+.1}%", (r.relative_performance - 1.0) * 100.0),
                        format!("{:+.1}%", r.std_reduction * 100.0),
                        format!("{:+.1}%", r.avg_bw_increase * 100.0),
                    ]),
                    Err(Error::InfeasiblePartitioning(why)) => {
                        eprintln!("skip {name}@{n}: {why}");
                        t.row(vec![name.clone(), n.to_string(), "DRAM".into(), "-".into(), "-".into()])
                    }
                    Err(e) => return Err(e),
                };
            }
        }
        print!("{}", t.title("partition sweep").render());
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
