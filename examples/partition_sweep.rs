//! Partition sweep over any model set — a configurable, parallel Fig-5.
//!
//! Scenarios (models × partition counts × bandwidth scales) fan out
//! across worker threads; the ranked report is byte-identical whatever
//! `--threads` is set to.
//!
//! ```bash
//! cargo run --release --example partition_sweep -- \
//!     --models resnet50,googlenet --partitions 1,2,4,8,16 \
//!     --bw-scales 1.0,0.75 --batches 6 --threads 0
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::config::AcceleratorConfig;
use trafficshape::sweep::{SweepGrid, SweepRunner, DEFAULT_SWEEP_MODELS};

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("partition_sweep", "parallel sweep of partition scenarios")
        .opt("models", "LIST", None, "comma-separated model names (default: 5-model zoo)")
        .opt("partitions", "LIST", Some("1,2,4,8,16"), "partition counts")
        .opt("bw-scales", "LIST", Some("1.0"), "memory-bandwidth multipliers")
        .opt("batches", "N", Some("6"), "steady-state batches")
        .opt("threads", "N", Some("0"), "worker threads (0 = all cores)")
        .opt("accel", "NAME", Some("knl_7210"), "accelerator preset");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };

    let run = || -> trafficshape::error::Result<()> {
        let accel = AcceleratorConfig::preset(m.get("accel").unwrap())?;
        let models = m
            .get_str_list("models")
            .unwrap_or_else(|| DEFAULT_SWEEP_MODELS.iter().map(|s| s.to_string()).collect());
        let grid = SweepGrid::new(&accel)
            .models(models)
            .partitions(m.get_usize_list("partitions")?.unwrap())
            .bandwidth_scales(m.get_f64_list("bw-scales")?.unwrap())
            .steady_batches(m.get_usize("batches")?.unwrap());
        let total = grid.len();
        let runner = SweepRunner::new(grid).threads(m.get_usize("threads")?.unwrap());
        let workers = runner.effective_threads();
        let report = runner.run()?;
        print!("{}", report.render());
        for (s, why) in report.infeasible_reasons() {
            eprintln!("note: {}: {why}", s.label());
        }
        println!(
            "{total} scenarios ({} completed, {} DRAM-infeasible) on {workers} worker thread(s)",
            report.completed_count(),
            report.infeasible_count(),
        );
        if let Some(best) = report.best() {
            let gain =
                best.metrics().map(|x| (x.relative_performance - 1.0) * 100.0).unwrap_or(0.0);
            println!("→ best: {} ({gain:+.1}%)", best.scenario.label());
        }
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
