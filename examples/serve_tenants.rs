//! Multi-tenant serving — co-scheduled machine slices vs time sharing.
//!
//! Two CNNs share one accelerator under identical offered load: each
//! tenant brings its own arrival stream and owns a FLOP-proportional
//! slice of the cores (co-scheduled), or the tenants take whole-machine
//! turns one quantum at a time (time-shared, the conventional schedule).
//! The question the offline mixed experiment could not answer: who wins
//! on *tail latency and goodput*, not just makespan?
//!
//! ```bash
//! cargo run --release --example serve_tenants -- \
//!     --tenants resnet50:0.2:120,vgg16:0.8:40 --duration 0.5
//!
//! # Let the co-scheduled split adapt at epoch boundaries:
//! cargo run --release --example serve_tenants -- \
//!     --tenants resnet50:0.5:200,vgg16:0.5:30 --rebalance --quantum-ms 10
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::config::AcceleratorConfig;
use trafficshape::serve::{ServeExperiment, TenantMode, TenantSpec};

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("serve_tenants", "multi-tenant serving: cosched vs time sharing")
        .opt("tenants", "LIST", Some("resnet50:0.2:120,vgg16:0.8:40"), "model:share:rate,...")
        .opt("duration", "S", Some("0.5"), "arrival window in seconds")
        .opt("seed", "N", Some("42"), "arrival-stream rng seed")
        .opt("queue-cap", "N", Some("0"), "per-partition queue bound (0 = unbounded)")
        .opt("slo-ms", "MS", Some("0"), "latency deadline per tenant (0 = none)")
        .opt("quantum-ms", "MS", Some("5"), "time-share quantum / rebalance window")
        .switch("rebalance", "move cores between slices at epoch boundaries")
        .opt("threads", "N", Some("0"), "worker threads (0 = all cores)")
        .opt("accel", "NAME", Some("knl_7210"), "accelerator preset");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };

    let run = || -> trafficshape::error::Result<()> {
        let accel = AcceleratorConfig::preset(m.get("accel").unwrap_or("knl_7210"))?;
        let mut specs =
            TenantSpec::parse_list(m.get("tenants").unwrap_or("resnet50:0.2:120,vgg16:0.8:40"))?;
        let cap = m.get_usize("queue-cap")?.unwrap_or(0);
        let slo = m.get_f64("slo-ms")?.unwrap_or(0.0);
        for t in &mut specs {
            t.queue_cap = cap;
            t.slo_ms = slo;
        }
        let offered: f64 = specs.iter().map(|t| t.arrival.mean_rate()).sum();
        println!(
            "{} tenant(s), {:.0} img/s offered — co-scheduled slices vs time sharing",
            specs.len(),
            offered
        );
        let template = specs[0].graph.clone();
        let curve = ServeExperiment::new(&accel, &template)
            .tenants(specs)
            .duration(m.get_f64("duration")?.unwrap_or(0.5))
            .seed(m.get_usize("seed")?.unwrap_or(42) as u64)
            .tenant_epoch_ms(m.get_f64("quantum-ms")?.unwrap_or(5.0))
            .tenant_rebalance(m.flag("rebalance"))
            .threads(m.get_usize("threads")?.unwrap_or(0))
            .run()?;
        print!("{}", curve.render());
        let co = curve.tenant_aggregate(TenantMode::Coscheduled);
        let ts = curve.tenant_aggregate(TenantMode::TimeShared);
        if let (Some(co), Some(ts)) = (co, ts) {
            let verdict = if co.latency.p99_ms < ts.latency.p99_ms {
                "co-scheduling wins the tail"
            } else {
                "time sharing wins the tail"
            };
            println!(
                "→ aggregate p99: co-scheduled {:.1} ms vs time-shared {:.1} ms — {verdict}",
                co.latency.p99_ms, ts.latency.p99_ms
            );
            println!(
                "→ goodput: co-scheduled {:.0} img/s vs time-shared {:.0} img/s",
                co.goodput_ips, ts.goodput_ips
            );
        }
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
