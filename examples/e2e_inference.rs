//! End-to-end driver — proves all three layers compose on a real
//! workload:
//!
//!   L1 Pallas conv kernel → L2 JAX TinyCNN stages → AOT HLO artifacts →
//!   L3 rust PJRT runtime + partitioned coordinator with traffic metering.
//!
//! Loads the artifacts built by `make artifacts`, self-checks every
//! compiled stage against the manifest's expected outputs (real
//! numerics, not shapes), then serves several hundred images through
//! 1..n partition workers and reports throughput and the metered
//! bandwidth statistics per configuration.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example e2e_inference -- --partitions 2 --batches 32
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::coordinator::{Coordinator, CoordinatorConfig};
use trafficshape::error::Error;
use trafficshape::runtime::{find_artifact_dir, Manifest};
use trafficshape::util::table::Table;

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("e2e_inference", "full-stack inference driver")
        .opt("partitions", "N", Some("2"), "max partition count to sweep")
        .opt("batches", "N", Some("32"), "total micro-batches per config")
        .opt("micro-batch", "N", Some("8"), "images per micro-batch")
        .opt("artifacts", "DIR", None, "artifact directory override");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };

    let run = || -> trafficshape::error::Result<()> {
        let dir = match m.get("artifacts") {
            Some(d) => std::path::PathBuf::from(d),
            None => find_artifact_dir()
                .ok_or_else(|| Error::Artifact("run `make artifacts` first".into()))?,
        };
        let manifest = Manifest::load(&dir)?;
        println!(
            "artifacts: {} ({} stages × {:?} batches, {} params)",
            dir.display(),
            manifest.stage_order.len(),
            manifest.batches,
            manifest.param_count
        );

        let max_parts = m.get_usize("partitions")?.unwrap().max(1);
        let total_batches = m.get_usize("batches")?.unwrap();
        let micro_batch = m.get_usize("micro-batch")?.unwrap();

        let cols = vec!["partitions", "images", "img/s", "traffic MB", "BW mean MB/s", "BW cov"];
        let mut table = Table::new(cols);
        let mut checksums = Vec::new();
        let mut parts = 1;
        while parts <= max_parts {
            let mut cfg = CoordinatorConfig::new(dir.clone());
            cfg.partitions = parts;
            cfg.total_batches = total_batches;
            cfg.micro_batch = micro_batch;
            cfg.self_check = parts == 1; // numerics verified once
            let report = Coordinator::new(cfg)?.run()?;
            println!(
                "{} partition(s): {} images in {:.2} s → {:.1} img/s (jobs {:?})",
                parts,
                report.images,
                report.wall_seconds,
                report.throughput_ips,
                report.jobs_per_worker
            );
            table.row(vec![
                parts.to_string(),
                report.images.to_string(),
                format!("{:.1}", report.throughput_ips),
                format!("{:.1}", report.total_traffic_bytes / 1e6),
                format!("{:.2}", report.bw.mean * 1e3),
                format!("{:.3}", report.bw.cov()),
            ]);
            checksums.push(report.logits_checksum);
            parts *= 2;
        }
        print!("{}", table.title("e2e sweep (TinyCNN, real PJRT compute)").render());

        // Same inputs → identical logits regardless of partitioning.
        for w in checksums.windows(2) {
            let delta = (w[0] - w[1]).abs();
            assert!(
                delta < 1e-3 * w[0].abs().max(1.0),
                "partitioning changed the numerics: {checksums:?}"
            );
        }
        println!("logits checksum invariant across partition counts: ok ({:.6})", checksums[0]);
        println!("note: single-CPU host — this demonstrates composition, not wall-clock scaling.");
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
