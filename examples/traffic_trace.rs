//! Dump Fig-1/Fig-6-style bandwidth traces to CSV for plotting.
//!
//! ```bash
//! cargo run --release --example traffic_trace -- --out /tmp/ts_trace
//! # → /tmp/ts_trace/fig1/trace.csv and /tmp/ts_trace/fig6/traces.csv
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::config::ExperimentConfig;
use trafficshape::experiments::run_by_id;

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("traffic_trace", "dump bandwidth traces as CSV")
        .opt("out", "DIR", Some("out/traces"), "output directory")
        .opt("samples", "N", Some("400"), "samples per trace")
        .opt("batches", "N", Some("4"), "steady-state batches");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };
    let run = || -> trafficshape::error::Result<()> {
        let mut cfg = ExperimentConfig::default();
        cfg.trace_samples = m.get_usize("samples")?.unwrap();
        cfg.steady_batches = m.get_usize("batches")?.unwrap();
        cfg.out_dir = m.get("out").unwrap().into();
        for id in ["fig1", "fig6"] {
            let out = run_by_id(id, &cfg)?;
            print!("{}", out.rendered);
            out.write_to(&cfg.out_dir)?;
            println!("wrote {}/{}/", cfg.out_dir.display(), id);
        }
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
