//! Serving latency under open-loop request traffic — the closed-the-loop
//! scenario: seeded Poisson (or bursty MMPP) arrivals feed per-partition
//! dynamic-batching queues, and every point of the throughput–latency
//! curve runs on the fluid engine so partitions contend for bandwidth
//! mid-burst.
//!
//! ```bash
//! cargo run --release --example serve_latency -- \
//!     --model resnet50 --partitions 1,2,4 --duration 0.5 --seed 42 \
//!     --arrival bursty --burstiness 6
//!
//! # Adaptive re-partitioning under a step-load profile:
//! cargo run --release --example serve_latency -- \
//!     --model resnet50 --partitions 1,2,4 --adaptive \
//!     --rate-profile 150:700:0.4 --duration 0.6
//!
//! # Error bars: 5 Monte-Carlo replications, mean ± 95% CI per row:
//! cargo run --release --example serve_latency -- \
//!     --model resnet50 --partitions 1,2 --arrival bursty --replications 5
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::config::AcceleratorConfig;
use trafficshape::model;
use trafficshape::serve::{
    roofline_capacity_ips, AdaptiveConfig, ArrivalKind, ArrivalProcess, ServeExperiment,
};

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("serve_latency", "throughput-latency curves for served requests")
        .opt("model", "NAME", Some("resnet50"), "model name")
        .opt("partitions", "LIST", Some("1,2,4"), "partition counts")
        .opt("rate", "LIST", None, "arrival rates in img/s (default: auto vs capacity)")
        .opt("duration", "S", Some("0.5"), "arrival window in seconds")
        .opt("seed", "N", Some("42"), "arrival-stream rng seed")
        .opt("replications", "N", Some("1"), "Monte-Carlo replications (mean ± 95% CI)")
        .opt("arrival", "NAME", Some("poisson"), "arrival process: poisson|bursty")
        .opt("burstiness", "X", Some("4"), "bursty only: burst-to-mean rate ratio")
        .opt("rate-profile", "L:H:P[:S]", None, "rate profile low:high:period[:step|ramp]")
        .switch("adaptive", "add a runtime-repartitioning row (candidates = --partitions)")
        .opt("epoch-ms", "MS", Some("50"), "adaptive: epoch (reconfig window) length")
        .opt("queue-cap", "N", Some("0"), "per-partition queue bound (0 = unbounded)")
        .opt("slo-ms", "MS", Some("0"), "latency deadline; stale work is shed (0 = none)")
        .opt("batch-timeout", "MS", Some("0"), "hold under-filled batches (0 = on idle)")
        .opt("threads", "N", Some("0"), "worker threads (0 = all cores)")
        .opt("accel", "NAME", Some("knl_7210"), "accelerator preset");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };

    let run = || -> trafficshape::error::Result<()> {
        let accel = AcceleratorConfig::preset(m.get("accel").unwrap_or("knl_7210"))?;
        let graph = model::by_name(m.get("model").unwrap_or("resnet50"))?;
        let burstiness = m.get_f64("burstiness")?.unwrap_or(4.0);
        let profile = m.get("rate-profile").map(ArrivalProcess::parse_profile).transpose()?;
        let arrival = match &profile {
            Some(p) => ArrivalKind::from_process(p).expect("parse_profile returns piecewise"),
            None => ArrivalKind::from_name(m.get("arrival").unwrap_or("poisson"), burstiness)?,
        };
        let partitions = m.get_usize_list("partitions")?.unwrap_or_else(|| vec![1, 2, 4]);
        let cap = roofline_capacity_ips(&accel, &graph);
        println!("{}: synchronous roofline capacity ≈ {cap:.0} img/s", graph.name);

        let mut exp = ServeExperiment::new(&accel, &graph)
            .partitions(partitions.clone())
            .arrival(arrival)
            .duration(m.get_f64("duration")?.unwrap_or(0.5))
            .seed(m.get_usize("seed")?.unwrap_or(42) as u64)
            .replications(m.get_usize("replications")?.unwrap_or(1))
            .queue_cap(m.get_usize("queue-cap")?.unwrap_or(0))
            .slo_ms(m.get_f64("slo-ms")?.unwrap_or(0.0))
            .batch_timeout_ms(m.get_f64("batch-timeout")?.unwrap_or(0.0))
            .threads(m.get_usize("threads")?.unwrap_or(0));
        if m.flag("adaptive") {
            let epoch_s = m.get_f64("epoch-ms")?.unwrap_or(50.0) / 1e3;
            exp = exp.adaptive(AdaptiveConfig::new(partitions).epoch_s(epoch_s));
        }
        if let Some(rates) = m.get_f64_list("rate")? {
            exp = exp.rates(rates);
        } else if let Some(p) = &profile {
            exp = exp.rates(vec![p.mean_rate()]);
        }
        let curve = exp.run()?;
        print!("{}", curve.render());
        if let Some(o) = curve.best_at_peak().and_then(|best| best.outcome()) {
            println!(
                "→ at peak load, {} partition(s) give p99 {:.1} ms at {:.0} img/s \
                 ({:.1}% dropped)",
                o.partitions,
                o.latency.p99_ms,
                o.throughput_ips,
                o.drop_rate * 100.0
            );
        }
        if let Some(s) = curve.best_at_peak().and_then(|best| best.stats.as_ref()) {
            println!(
                "→ across {} replications, p99 = {} ms (mean ± 95% CI)",
                s.replications(),
                s.p99_ms.render(1)
            );
        }
        if let Some(o) = curve.adaptive_at(curve.peak_rate()) {
            println!(
                "→ adaptive: {} reconfiguration(s), partitions {} — p99 {:.1} ms",
                o.reconfigurations(),
                o.trajectory_string(),
                o.latency.p99_ms
            );
        }
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
