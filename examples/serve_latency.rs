//! Serving latency under open-loop request traffic — the closed-the-loop
//! scenario: seeded Poisson (or bursty MMPP) arrivals feed per-partition
//! dynamic-batching queues, and every point of the throughput–latency
//! curve runs on the fluid engine so partitions contend for bandwidth
//! mid-burst.
//!
//! ```bash
//! cargo run --release --example serve_latency -- \
//!     --model resnet50 --partitions 1,2,4 --duration 0.5 --seed 42 \
//!     --arrival bursty --burstiness 6
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::config::AcceleratorConfig;
use trafficshape::model;
use trafficshape::serve::{roofline_capacity_ips, ArrivalKind, ServeExperiment};

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("serve_latency", "throughput-latency curves for served requests")
        .opt("model", "NAME", Some("resnet50"), "model name")
        .opt("partitions", "LIST", Some("1,2,4"), "partition counts")
        .opt("rate", "LIST", None, "arrival rates in img/s (default: auto vs capacity)")
        .opt("duration", "S", Some("0.5"), "arrival window in seconds")
        .opt("seed", "N", Some("42"), "arrival-stream rng seed")
        .opt("arrival", "NAME", Some("poisson"), "arrival process: poisson|bursty")
        .opt("burstiness", "X", Some("4"), "bursty only: burst-to-mean rate ratio")
        .opt("threads", "N", Some("0"), "worker threads (0 = all cores)")
        .opt("accel", "NAME", Some("knl_7210"), "accelerator preset");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };

    let run = || -> trafficshape::error::Result<()> {
        let accel = AcceleratorConfig::preset(m.get("accel").unwrap())?;
        let graph = model::by_name(m.get("model").unwrap())?;
        let burstiness = m.get_f64("burstiness")?.unwrap();
        let arrival = ArrivalKind::from_name(m.get("arrival").unwrap(), burstiness)?;
        let cap = roofline_capacity_ips(&accel, &graph);
        println!("{}: synchronous roofline capacity ≈ {cap:.0} img/s", graph.name);

        let mut exp = ServeExperiment::new(&accel, &graph)
            .partitions(m.get_usize_list("partitions")?.unwrap())
            .arrival(arrival)
            .duration(m.get_f64("duration")?.unwrap())
            .seed(m.get_usize("seed")?.unwrap() as u64)
            .threads(m.get_usize("threads")?.unwrap());
        if let Some(rates) = m.get_f64_list("rate")? {
            exp = exp.rates(rates);
        }
        let curve = exp.run()?;
        print!("{}", curve.render());
        if let Some(best) = curve.best_at_peak() {
            let o = best.outcome().expect("best point is completed");
            println!(
                "→ at peak load, {} partition(s) give p99 {:.1} ms at {:.0} img/s",
                best.partitions, o.latency.p99_ms, o.throughput_ips
            );
        }
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
