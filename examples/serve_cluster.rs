//! Fleet-scale serving — a heterogeneous cluster behind one front door.
//!
//! Three machines of different sizes and memory bandwidths serve one
//! open-loop stream at more than any single machine's capacity. The
//! router decides who gets each request: load-blind round-robin drowns
//! the small machine while the big one idles; join-shortest-queue and
//! power-of-two-choices spread the backlog by expected wait — the
//! paper's statistical-shaping argument applied across machines instead
//! of across partitions. Add `--fail` to take a machine down mid-run and
//! watch its backlog drain to the survivors with every request accounted
//! for.
//!
//! ```bash
//! cargo run --release --example serve_cluster -- \
//!     --machines 64:1.0,32:0.75,16:0.5 --router po2c --rate 1500
//!
//! # Compare the routers on the same seeded stream:
//! cargo run --release --example serve_cluster -- --router round_robin
//!
//! # Fail the big machine at 100 ms and restart it at 300 ms:
//! cargo run --release --example serve_cluster -- --fail 0@0.1:0.3
//! ```

use trafficshape::cli::CommandSpec;
use trafficshape::config::AcceleratorConfig;
use trafficshape::prelude::{
    ClusterConfig, ClusterSimulator, FailureEvent, MachineConfig, RouterPolicy,
};
use trafficshape::serve::ServeConfig;

fn main() -> std::process::ExitCode {
    let spec = CommandSpec::new("serve_cluster", "fleet-scale serving over a machine cluster")
        .opt("model", "NAME", Some("resnet50"), "fleet-wide model")
        .opt("machines", "LIST", Some("64:1.0,32:0.75,16:0.5"), "CORES[:BW_SCALE],...")
        .opt("router", "NAME", Some("po2c"), "front door: round_robin|jsq|po2c")
        .opt("fail", "LIST", None, "failures: MACHINE@AT_S[:RESTART_S],...")
        .opt("rate", "N", Some("1500"), "fleet arrival rate in img/s")
        .opt("duration", "S", Some("0.5"), "arrival window in seconds")
        .opt("seed", "N", Some("42"), "arrival-stream + router rng seed")
        .opt("partitions", "N", Some("4"), "partitions per machine")
        .opt("slo-ms", "MS", Some("50"), "latency deadline (0 = none)")
        .opt("threads", "N", Some("0"), "worker threads (0 = all cores)")
        .opt("accel", "NAME", Some("knl_7210"), "base accelerator preset");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m = match spec.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return std::process::ExitCode::from(2);
        }
    };

    let run = || -> trafficshape::error::Result<()> {
        let accel = AcceleratorConfig::preset(m.get("accel").unwrap_or("knl_7210"))?;
        let graph = trafficshape::model::by_name(m.get("model").unwrap_or("resnet50"))?;
        let mut serve = ServeConfig::default();
        serve.rates = vec![m.get_f64("rate")?.unwrap_or(1500.0)];
        serve.duration_s = m.get_f64("duration")?.unwrap_or(0.5);
        serve.seed = m.get_usize("seed")?.unwrap_or(42) as u64;
        serve.partitions = vec![m.get_usize("partitions")?.unwrap_or(4)];
        serve.slo_ms = m.get_f64("slo-ms")?.unwrap_or(50.0);
        let mut machines =
            MachineConfig::parse_list(m.get("machines").unwrap_or("64:1.0,32:0.75,16:0.5"))?;
        for mc in &mut machines {
            mc.serve = serve.clone();
        }
        let cfg = ClusterConfig {
            machines,
            router: RouterPolicy::from_name(m.get("router").unwrap_or("po2c"))?,
            failures: match m.get("fail") {
                Some(f) => FailureEvent::parse_list(f)?,
                None => Vec::new(),
            },
            serve,
        };
        let out = ClusterSimulator::from_config(&accel, &graph, cfg)
            .threads(m.get_usize("threads")?.unwrap_or(0))
            .run()?;
        print!("{}", out.render());
        let drop_pct = 100.0 * out.fleet.dropped as f64 / out.requests.max(1) as f64;
        println!(
            "→ {} router: fleet p99 {:.1} ms, goodput {:.0} img/s, {:.1}% dropped, \
             BW {:.1} ± {:.1} GB/s over {} machines",
            out.router.name(),
            out.fleet.latency.p99_ms,
            out.fleet.goodput_ips,
            drop_pct,
            out.fleet.bw.mean,
            out.fleet.bw.std,
            out.machines.len()
        );
        Ok(())
    };
    match run() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}
