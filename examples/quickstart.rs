//! Quickstart: the library in ~20 lines.
//!
//! Builds ResNet-50, partitions the KNL-class accelerator 4 ways, and
//! prints the paper's three metrics for this configuration.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use trafficshape::prelude::*;

fn main() -> Result<()> {
    // The paper's testbed: 64 cores, 6 TFLOPS, MCDRAM @ ~400 GB/s, 16 GB.
    let accel = AcceleratorConfig::knl_7210();

    // The paper's headline workload.
    let net = resnet50();
    println!(
        "{}: {} layers, {:.1} M params, {:.1} GFLOP/image",
        net.name,
        net.len(),
        net.param_elems() as f64 / 1e6,
        net.flops_per_image() / 1e9
    );

    // Synchronous baseline vs 4 asynchronous partitions.
    let report = PartitionExperiment::new(&accel, &net)
        .partitions(4)
        .steady_batches(6)
        .run()?;

    println!("\n4 partitions vs synchronous baseline:");
    println!(
        "  relative performance : {:+.1}%  (paper: +8.0% at best n)",
        (report.relative_performance - 1.0) * 100.0
    );
    println!(
        "  σ(BW) reduction      : {:+.1}%  (paper: −36.2%)",
        report.std_reduction * 100.0
    );
    println!(
        "  mean BW increase     : {:+.1}%  (paper: +15.2%)",
        report.avg_bw_increase * 100.0
    );
    println!(
        "  baseline: mean {:.1} GB/s σ {:.1} | shaped: mean {:.1} GB/s σ {:.1}",
        report.baseline.bw.mean,
        report.baseline.bw.std,
        report.shaped.bw.mean,
        report.shaped.bw.std
    );
    Ok(())
}
